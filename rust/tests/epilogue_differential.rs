//! Differential suite for the fused GEMM epilogues: applying
//! bias / ReLU / requantize-for-the-consumer per cache-resident output
//! tile (`gemm::Epilogue`) must be **bit-for-bit** what the unfused
//! pipeline — the same GEMM followed by the standalone
//! `nn::vecmath` passes — produces, for every `ArithKind`, at every
//! ISA this machine can dispatch to (`isa::detected`), across edge
//! shapes (m = 0, k = 0, n = 1, non-divisible-by-tile) and thread
//! counts.
//!
//! Because fused and unfused run the *same kernel*, the bitwise
//! contract holds for every kind including the AVX2+FMA f32 tier.
//! Only the comparison against the scalar `reference` oracle applies
//! the `fma_f32_bound` tolerance to that one kernel — the same policy
//! as `tests/gemm_differential.rs`.
//!
//! The suite also pins the *structural* half of the fusion contract:
//! a `dense(..)+relu` / `conv(..)+relu` forward pass performs ZERO
//! standalone bias/relu tensor walks (`vecmath::pass_counts`), and a
//! fully-fused network forward — including the
//! requantize-for-the-consumer epilogue ahead of maxpool — equals a
//! hand-built unfused forward bit-for-bit (sound because pack-time
//! conditioning is idempotent over each provider's lattice and
//! `maxpool2` commutes with the monotone `quantize`; both properties
//! are themselves checked below).
//!
//! Run under `LOP_FORCE_ISA=scalar` to pin the portable epilogues on
//! any machine (CI runs both legs).  Scale the randomized sweeps with
//! `LOP_PROP_CASES=N`; failures print a replay snippet via
//! `util::prop`.

use lop::approx::arith::ArithKind;
use lop::nn::conv::conv2d;
use lop::nn::gemm::reference::gemm_reference;
use lop::nn::gemm::{default_threads, fma_f32_bound, isa, Epilogue,
                    GemmPlan, Isa};
use lop::nn::layers::maxpool2;
use lop::nn::quantizer::quantize_tensor;
use lop::nn::spec::{Activation, LayerKind};
use lop::nn::vecmath;
use lop::nn::{Model, NetSpec, ReprMap, Tensor};
use lop::util::prng::Rng;
use lop::util::prop;

/// One representative per `ArithKind` variant plus width variations —
/// the same palette as `tests/gemm_differential.rs`.
const KINDS: [&str; 11] = [
    "float32",
    "FI(6,8)",
    "FI(3,4)",
    "FI(8,11)",
    "H(6,8,6)",
    "H(8,8,14)",
    "FL(4,9)",
    "FL(5,10)",
    "I(5,10)",
    "I(4,9,2)",
    "binxnor",
];

/// Consumer representations the `BiasReluQuant` epilogue snaps onto —
/// one per provider family so the requantize leg covers every lattice.
const CONSUMERS: [&str; 6] =
    ["FI(3,4)", "float32", "FL(4,9)", "H(6,8,6)", "I(5,10)", "binxnor"];

/// Epilogue shapes under test, by index: bias only, bias + ReLU,
/// bias + ReLU + requantize-for-the-consumer.
const VARIANTS: usize = 3;

fn rand_operands(rng: &mut Rng, kind: &ArithKind, m: usize, k: usize,
                 n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    // activations include exact zeros (zero-skip neutrality), weights
    // pre-quantized per the layer contract; bias includes exact zeros
    // and negatives so ReLU genuinely clamps some columns
    let x: Vec<f32> = (0..m * k)
        .map(|_| {
            if rng.below(4) == 0 {
                0.0
            } else {
                (rng.normal() * 2.0) as f32
            }
        })
        .collect();
    let w: Vec<f32> = (0..k * n)
        .map(|_| kind.quantize(rng.normal() as f32))
        .collect();
    let bias: Vec<f32> = (0..n)
        .map(|_| {
            if rng.below(5) == 0 {
                0.0
            } else {
                rng.normal() as f32
            }
        })
        .collect();
    (x, w, bias)
}

fn make_epilogue<'a>(variant: usize, bias: &'a [f32],
                     quant: &ArithKind) -> Epilogue<'a> {
    match variant {
        0 => Epilogue::Bias { bias },
        1 => Epilogue::BiasRelu { bias },
        _ => Epilogue::BiasReluQuant { bias, quant: *quant },
    }
}

/// The unfused pipeline the epilogue must reproduce bit-for-bit: the
/// standalone `vecmath` passes, in epilogue order, over a finished
/// GEMM output.
fn separate_passes(variant: usize, out: &mut [f32], bias: &[f32],
                   quant: &ArithKind) {
    if out.is_empty() {
        return;
    }
    vecmath::add_bias_in_place(out, bias);
    if variant >= 1 {
        vecmath::relu_in_place(out);
    }
    if variant >= 2 {
        vecmath::quantize_in_place(quant, out);
    }
}

/// Fused run (per-call-packed *and* prepacked weight paths) vs the
/// same plan run unfused + `separate_passes`, bitwise, at every thread
/// count.  The plan must already carry prepacked panels for (k, n).
fn fused_vs_separate(plan: &GemmPlan, x: &[f32], w: &[f32],
                     bias: &[f32], m: usize, k: usize, n: usize,
                     variant: usize, quant: &ArithKind,
                     thread_counts: &[usize]) -> Result<(), String> {
    let ep = make_epilogue(variant, bias, quant);
    let mut want = vec![f32::NAN; m * n];
    plan.run(x, w, m, k, n, &mut want, 1);
    separate_passes(variant, &mut want, bias, quant);
    for &threads in thread_counts {
        for prepacked in [false, true] {
            let mut got = vec![f32::NAN; m * n];
            if prepacked {
                plan.run_prepacked_with(x, m, &mut got, threads, &ep);
            } else {
                plan.run_with(x, w, m, k, n, &mut got, threads, &ep);
            }
            for (i, (g, ww)) in got.iter().zip(&want).enumerate() {
                if g.to_bits() != ww.to_bits() {
                    return Err(format!(
                        "variant {variant} [{}] ({m}x{k}x{n}, \
                         threads={threads}, prepacked={prepacked}, \
                         quant={}): out[{i}] = {g} ({:#010x}), \
                         separate passes give {ww} ({:#010x})",
                        plan.kernel_name(),
                        quant.name(),
                        g.to_bits(),
                        ww.to_bits()
                    ));
                }
            }
        }
    }
    Ok(())
}

/// (m, k, n) edge shapes: empty output, empty reduction (epilogue
/// still applies to the zero GEMM term), single column, single cell,
/// exact tile multiples, tile + 1, and shapes crossing the KC = 256
/// depth blocking.
const EDGE_SHAPES: [(usize, usize, usize); 8] = [
    (0, 5, 3),
    (3, 0, 4),
    (5, 7, 1),
    (1, 1, 1),
    (4, 64, 4),
    (8, 129, 9),
    (13, 300, 11),
    (33, 257, 18),
];

#[test]
fn fused_matches_separate_passes_edge_shapes_per_isa() {
    let mut rng = Rng::new(0xE9);
    for tier in isa::detected() {
        for (ki, ks) in KINDS.iter().enumerate() {
            let kind = ArithKind::parse(ks).unwrap();
            for (si, &(m, k, n)) in EDGE_SHAPES.iter().enumerate() {
                let (x, w, bias) =
                    rand_operands(&mut rng, &kind, m, k, n);
                let quant = ArithKind::parse(
                    CONSUMERS[(ki + si) % CONSUMERS.len()])
                    .unwrap();
                let mut plan = GemmPlan::with_isa(&kind, tier);
                plan.prepack(&w, k, n);
                for variant in 0..VARIANTS {
                    fused_vs_separate(&plan, &x, &w, &bias, m, k, n,
                                      variant, &quant,
                                      &[1, default_threads()])
                        .unwrap();
                }
            }
        }
    }
}

#[test]
fn randomized_fused_matches_separate_passes_per_isa() {
    for tier in isa::detected() {
        for (ki, ks) in KINDS.iter().enumerate() {
            let kind = ArithKind::parse(ks).unwrap();
            prop::check_msg(
                &format!("fused == separate passes ({ks} @ {tier})"),
                0xEF00 + ki as u64,
                12,
                |rng| {
                    // m/n edges straddle the MR/NR tiles in play;
                    // ~1 case in 5 is big enough that the
                    // default-threads leg genuinely spawns threads
                    let (m, n) = if rng.below(5) == 0 {
                        (64 + rng.below(17) as usize,
                         256 + rng.below(9) as usize)
                    } else {
                        (rng.below(34) as usize,
                         1 + rng.below(32) as usize)
                    };
                    let k = rng.below(97) as usize;
                    let variant = rng.below(VARIANTS as u64) as usize;
                    let ci =
                        rng.below(CONSUMERS.len() as u64) as usize;
                    (m, k, n, variant, ci, rng.next_u64())
                },
                |&(m, k, n, variant, ci, seed)| {
                    let mut rng = Rng::new(seed);
                    let (x, w, bias) =
                        rand_operands(&mut rng, &kind, m, k, n);
                    let quant =
                        ArithKind::parse(CONSUMERS[ci]).unwrap();
                    let mut plan = GemmPlan::with_isa(&kind, tier);
                    plan.prepack(&w, k, n);
                    fused_vs_separate(&plan, &x, &w, &bias, m, k, n,
                                      variant, &quant,
                                      &[1, default_threads()])
                },
            );
        }
    }
}

/// Fused output vs the pre-tiling `reference` oracle + separate
/// passes: bitwise for every kernel except AVX2+FMA f32, which is
/// held to `fma_f32_bound` (bias adds the same term to both sides and
/// ReLU is 1-Lipschitz, so the GEMM bound survives both; the
/// requantize variant is excluded there — rounding can amplify a
/// sub-bound difference across a lattice step — and is covered
/// bitwise against the same-kernel pipeline above).
#[test]
fn fused_matches_reference_oracle_per_isa() {
    let mut rng = Rng::new(0xAC);
    for tier in isa::detected() {
        for (ki, ks) in KINDS.iter().enumerate() {
            let kind = ArithKind::parse(ks).unwrap();
            let plan = GemmPlan::with_isa(&kind, tier);
            let fma = kind == ArithKind::Float32
                && plan.isa() != Isa::Scalar;
            for (si, &(m, k, n)) in EDGE_SHAPES.iter().enumerate() {
                let (x, w, bias) =
                    rand_operands(&mut rng, &kind, m, k, n);
                let quant = ArithKind::parse(
                    CONSUMERS[(ki + si) % CONSUMERS.len()])
                    .unwrap();
                let bound = if fma {
                    fma_f32_bound(&x, &w, m, k, n)
                } else {
                    Vec::new()
                };
                let variants = if fma { 2 } else { VARIANTS };
                for variant in 0..variants {
                    let mut want = vec![f32::NAN; m * n];
                    gemm_reference(&kind, &x, &w, m, k, n, &mut want,
                                   1);
                    separate_passes(variant, &mut want, &bias, &quant);
                    let ep = make_epilogue(variant, &bias, &quant);
                    let mut got = vec![f32::NAN; m * n];
                    plan.run_with(&x, &w, m, k, n, &mut got, 1, &ep);
                    for (i, (g, ww)) in
                        got.iter().zip(&want).enumerate()
                    {
                        let ok = if fma {
                            (*g as f64 - *ww as f64).abs() <= bound[i]
                        } else {
                            g.to_bits() == ww.to_bits()
                        };
                        assert!(
                            ok,
                            "{ks}@{tier} variant {variant} \
                             ({m}x{k}x{n}): out[{i}] = {g}, \
                             reference pipeline gives {ww}"
                        );
                    }
                }
            }
        }
    }
}

/// The soundness leg behind fusing the *consumer's* requantize into
/// the producer's epilogue: every provider's `quantize` is idempotent
/// over its own lattice and weakly monotone (so it commutes with
/// `maxpool2`'s running max).
#[test]
fn quantize_is_idempotent_and_monotone() {
    for ks in KINDS {
        let kind = ArithKind::parse(ks).unwrap();
        prop::check(
            &format!("quantize idempotent + monotone ({ks})"),
            0x1D + ks.len() as u64,
            256,
            |rng| {
                let v = match rng.below(8) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => (rng.normal() * 1000.0) as f32, // saturating
                    _ => (rng.normal() * 4.0) as f32,
                };
                (v, (rng.normal() * 4.0) as f32)
            },
            |&(a, b)| {
                let qa = kind.quantize(a);
                let idem = kind.quantize(qa).to_bits() == qa.to_bits();
                let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
                let mono = kind.quantize(lo) <= kind.quantize(hi);
                idem && mono
            },
        );
    }
}

/// The structural acceptance pin: a fused `conv+relu` / `dense+relu`
/// forward performs ZERO standalone elementwise tensor passes — bias,
/// ReLU and the consumer requantize all ride the GEMM epilogue.
/// `forward_capture` must still run the standalone ReLU (it profiles
/// pre-activation ranges) but never a standalone bias pass.
#[test]
fn fused_forward_runs_zero_standalone_elementwise_passes() {
    let spec = NetSpec::parse(
        "8x8x1: conv(3x3,4,pad=1)+relu+pool | dense(6)+relu | dense(3)",
    )
    .unwrap();
    let model = Model::synthetic(spec.clone(), 41);
    let cfg =
        ReprMap::parse_for(&spec, "FI(6,8)|FL(4,9)|float32").unwrap();
    let net = model.prepare(&cfg);
    let x = spec.synthetic_input(2, 42);

    // threads = 1 keeps all layer work on this thread, so the
    // thread-local counters see every standalone pass there is
    let before = vecmath::pass_counts();
    let out = net.forward(&x, 1);
    let after = vecmath::pass_counts();
    assert_eq!(out.shape, vec![2, 3]);
    assert_eq!(
        after, before,
        "fused forward must not run any standalone vecmath pass"
    );

    let before = vecmath::pass_counts();
    let (_, ranges) = net.forward_capture(&x, 1);
    let after = vecmath::pass_counts();
    assert_eq!(ranges.len(), 3);
    assert_eq!(after.bias - before.bias, 0,
               "capture must still fuse the bias");
    assert_eq!(after.relu - before.relu, 2,
               "capture applies standalone ReLU per activated layer");
    assert_eq!(after.quantize - before.quantize, 0);
}

/// Hand-built unfused forward from the public pieces: per-call
/// quantized weights, GEMM with `Epilogue::None`, then the standalone
/// vecmath bias/ReLU passes and `maxpool2`.  No requantize pass — the
/// next layer's GEMM conditions its activations on entry, which is
/// where the idempotence + pool-commutation argument earns its keep.
fn unfused_forward(model: &Model, cfg: &ReprMap, x: &Tensor,
                   threads: usize) -> Tensor {
    let spec = model.spec();
    let b = x.shape[0];
    let mut cur: Option<Tensor> = None;
    for (li, layer) in spec.layers().iter().enumerate() {
        let kind = cfg.kind(li);
        let w = &model.params[&format!("{}_w", layer.name)];
        let bias =
            quantize_tensor(kind, &model.params
                [&format!("{}_b", layer.name)]);
        let plan = GemmPlan::new(kind);
        let mut z = match layer.kind {
            LayerKind::Conv2d { kh, kw, cout, pad, .. } => {
                let inp = cur.as_ref().unwrap_or(x);
                let (h, wd) = (inp.shape[1], inp.shape[2]);
                let rows = w.len() / cout;
                let w2 = quantize_tensor(kind, w)
                    .reshape(vec![rows, cout]);
                conv2d(&plan, inp, &w2, kh, kw, pad, threads)
                    .reshape(vec![b, h, wd, cout])
            }
            LayerKind::Dense { d_in, d_out } => {
                let flat = match cur.take() {
                    Some(t) => t.reshape(vec![b, d_in]),
                    None => {
                        Tensor::new(vec![b, d_in], x.data.clone())
                    }
                };
                let w2 = quantize_tensor(kind, w);
                let mut out = Tensor::zeros(vec![b, d_out]);
                plan.run(&flat.data, &w2.data, b, d_in, d_out,
                         &mut out.data, threads);
                out
            }
        };
        vecmath::add_bias_in_place(&mut z.data, &bias.data);
        if layer.activation == Activation::Relu {
            vecmath::relu_in_place(&mut z.data);
        }
        if layer.pool {
            z = maxpool2(&z);
        }
        cur = Some(z);
    }
    cur.expect("spec has at least one layer")
}

/// End-to-end: the fully-fused network forward — including the
/// requantize-for-the-consumer epilogue running *before* maxpool —
/// equals the hand-built unfused forward bit-for-bit, for uniform and
/// mixed configurations, at every thread count.  Bitwise even for
/// f32 at AVX2: both paths run the same kernels.
#[test]
fn fused_network_forward_matches_unfused_reference() {
    let spec = NetSpec::parse(
        "8x8x2: conv(3x3,4,pad=1)+relu+pool | \
         conv(3x3,6,pad=1)+relu | dense(5)+relu | dense(3)",
    )
    .unwrap();
    let model = Model::synthetic(spec.clone(), 71);
    let x = spec.synthetic_input(3, 72);
    for cs in [
        "float32",
        "FI(6,8)|FI(3,4)|H(6,8,6)|FL(4,9)",
        "I(5,10)|binxnor|FI(6,8)|float32",
    ] {
        let cfg = if cs.contains('|') {
            ReprMap::parse_for(&spec, cs).unwrap()
        } else {
            ReprMap::uniform_for(&spec,
                                 ArithKind::parse(cs).unwrap())
        };
        let net = model.prepare(&cfg);
        for threads in [1, default_threads()] {
            let fused = net.forward(&x, threads);
            let want = unfused_forward(&model, &cfg, &x, threads);
            assert_eq!(fused.shape, want.shape, "{cs}");
            for (i, (g, ww)) in
                fused.data.iter().zip(&want.data).enumerate()
            {
                assert_eq!(
                    g.to_bits(),
                    ww.to_bits(),
                    "{cs} (threads={threads}): logits[{i}] = {g}, \
                     unfused reference gives {ww}"
                );
            }
        }
    }
}
