//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The offline toolchain image has no crates.io registry cache, so the
//! real `anyhow` cannot be resolved; this shim provides the (small)
//! subset of its API that the `lop` crate uses, with compatible
//! semantics:
//!
//! * [`Error`] — an opaque error carrying a display message and a
//!   context chain,
//! * [`Result`] — `std::result::Result` with `Error` as the default
//!   error type,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — ad-hoc error construction,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result` and `Option`.
//!
//! Swapping back to the real crate is a one-line change in the root
//! `Cargo.toml`; no call site needs to change.

use std::fmt;

/// Opaque error: a message plus prepended context strings.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (the real crate's
    /// `Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context line, as `Context::context` does.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on the real anyhow prints the whole context chain; the
        // shim stores the chain pre-joined, so both forms are identical.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

/// Any std error converts implicitly, so `?` works on io/parse/... errors
/// inside functions returning [`Result`].
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `std::result::Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// [`bail!`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/anyhow-shim-test")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_and_context() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 42;
        let e = anyhow!("x = {x}");
        assert_eq!(e.to_string(), "x = 42");
        let e = anyhow!("x = {}", x);
        assert_eq!(e.to_string(), "x = 42");
        let e = anyhow!(String::from("owned message"));
        assert_eq!(e.to_string(), "owned message");

        fn bails(flag: bool) -> Result<()> {
            ensure!(!flag, "flag was {}", flag);
            bail!("always fails")
        }
        assert_eq!(bails(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(bails(false).unwrap_err().to_string(), "always fails");
    }

    #[test]
    fn alternate_display_matches_plain() {
        let e = anyhow!("leaf").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer: mid: leaf");
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
