//! Perf utility: batch-64 PJRT forward latency per artifact variant —
//! the measurement behind EXPERIMENTS.md §Perf (L2 path).
//!
//!     cargo run --release --example pjrt_speed

use lop::approx::arith::ArithKind;
use lop::data::Dataset;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::{ArtifactDir, ModelRunner};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let art = ArtifactDir::discover()?;
    let ds = Dataset::load(&art.dataset_path())?;
    let mut runner = ModelRunner::new(art)?;
    let idx: Vec<usize> = (0..64).collect();
    let x = ds.batch(&ds.test, &idx);
    let spec = NetSpec::paper_dcnn();
    for cfg in [
        ReprMap::uniform_for(&spec, ArithKind::Float32),
        ReprMap::parse_for(&spec, "FI(6,8)").unwrap(),
        ReprMap::parse_for(&spec, "FL(4,9)").unwrap(),
    ] {
        runner.forward(&cfg, &x)?; // compile + warm
        let t0 = Instant::now();
        for _ in 0..5 {
            runner.forward(&cfg, &x)?;
        }
        let per = t0.elapsed() / 5;
        println!("{:<10} batch64 fwd: {:?} ({:.1} img/s)", cfg.name(),
                 per, 64.0 / per.as_secs_f64());
    }
    Ok(())
}
