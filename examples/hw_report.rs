//! Hardware cost analysis (paper Table 5) plus two ablation sweeps the
//! paper's discussion motivates: energy efficiency vs fixed-point width,
//! and the DRUM width trade-off.
//!
//!     cargo run --release --example hw_report

use anyhow::Result;
use lop::approx::arith::ArithKind;
use lop::hw::datapath::{Datapath, ARRIA10, N_PE};
use lop::hw::report::{format_table, hw_report, table5_kinds};
use lop::hw::rtl::datapath_verilog;

fn main() -> Result<()> {
    // --- the paper's Table 5 ------------------------------------------------
    println!("Table 5 — {} x PE datapath on {}:\n", N_PE, ARRIA10.name);
    print!("{}", format_table(&hw_report(&table5_kinds())));

    // --- ablation 1: FI(6, f) width sweep ------------------------------------
    println!("\nAblation: energy efficiency vs fixed-point fractional \
              width (FI(6, f)):");
    println!("{:<10} {:>9} {:>11} {:>9} {:>10}", "repr", "ALMs",
             "clock MHz", "power W", "Gops/J");
    for f in [4u32, 6, 8, 10, 12, 14] {
        let k = ArithKind::parse(&format!("FI(6,{f})")).unwrap();
        let dp = Datapath::synthesize(&k, N_PE);
        println!("{:<10} {:>9.0} {:>11.2} {:>9.2} {:>10.2}", k.name(),
                 dp.alms, dp.fmax_mhz, dp.power_w, dp.gops_per_j);
    }

    // --- ablation 2: DRUM width on H(6, 8, t) --------------------------------
    println!("\nAblation: DRUM multiplier width t on H(6, 8, t) \
              (smaller t = smaller multiplier, larger error):");
    println!("{:<12} {:>9} {:>6} {:>11} {:>10}", "repr", "ALMs", "DSPs",
             "clock MHz", "Gops/J");
    for t in [4u32, 6, 8, 10, 12, 14] {
        let k = ArithKind::parse(&format!("H(6,8,{t})")).unwrap();
        let dp = Datapath::synthesize(&k, N_PE);
        println!("{:<12} {:>9.0} {:>6} {:>11.2} {:>10.2}", k.name(),
                 dp.alms, dp.dsps, dp.fmax_mhz, dp.gops_per_j);
    }

    // --- the ScaLop netlist view (paper §4.4) --------------------------------
    let k = ArithKind::parse("FI(6,8)").unwrap();
    println!("\nStructural netlist for one FI(6,8) PE (ScaLop view):");
    let v = datapath_verilog(&k, 1);
    println!("{v}");
    println!("hw_report OK");
    Ok(())
}
