//! Quickstart: describe the paper's DCNN with the `NetSpec` builder,
//! load the AOT artifacts into it, classify a few test digits under
//! float32 and FI(6, 8), and show that the narrow fixed-point
//! representation keeps the predictions (the paper's headline claim
//! for FI(6, 8), Table 4).
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use lop::approx::arith::ArithKind;
use lop::data::Dataset;
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::{ArtifactDir, ModelRunner};

fn main() -> Result<()> {
    // 1. the topology, built layer by layer (shape-checked as it
    //    grows).  This is exactly `NetSpec::paper_dcnn()` — spelled
    //    out here to show the builder; swap layers freely and the
    //    whole stack (prepare, serving, DSE) follows the spec.
    let spec = NetSpec::builder([28, 28, 1])
        .conv2d(5, 5, 32, 2)
        .relu()
        .pool()
        .conv2d(5, 5, 64, 2)
        .relu()
        .pool()
        .dense(1024)
        .relu()
        .dense(10)
        .build()
        .map_err(anyhow::Error::msg)?;
    assert!(spec.is_paper_dcnn());
    println!("model: {spec}");
    println!("       ({} layers, {} parameters)", spec.len(),
             spec.param_count());

    // 2. artifacts: HLO text + weights + dataset, produced by `make
    //    artifacts` (python runs once at build time, never here)
    let art = ArtifactDir::discover()?;
    println!("artifacts at {:?} (baseline accuracy {:.4})", art.root,
             art.baseline_accuracy);
    let model = Model::load(spec.clone(), &art.weights_path())?;
    let ds = Dataset::load(&art.dataset_path())?;

    // 3. a batch of test digits
    let idx: Vec<usize> = (0..16).collect();
    let x = ds.batch(&ds.test, &idx);
    let labels = &ds.test.labels[0..16];

    // 4. run float32 on the PJRT runtime (XLA-compiled artifact)
    let mut runner = ModelRunner::new(art)?;
    let f32cfg = ReprMap::uniform_for(&spec, ArithKind::Float32);
    let f32_pred = runner.forward(&f32cfg, &x)?.argmax_rows();

    // 5. the same batch under the paper's winning FI(6, 8) config —
    //    one ArithKind per layer, arity checked against the spec; the
    //    PJRT fake-quant path and the bit-accurate Rust engine agree
    let fi = ReprMap::parse_for(&spec, "FI(6,8)")
        .map_err(anyhow::Error::msg)?;
    let fi_pjrt = runner.forward(&fi, &x)?.argmax_rows();
    let fi_engine = model.prepare(&fi).predict(&x, 0);

    println!("\n{:<8} {:>6} {:>8} {:>10} {:>12}", "image", "label",
             "float32", "FI(6,8)", "FI engine");
    for i in 0..16 {
        println!("{:<8} {:>6} {:>8} {:>10} {:>12}", i, labels[i],
                 f32_pred[i], fi_pjrt[i], fi_engine[i]);
    }
    let agree = fi_pjrt.iter().zip(&f32_pred).filter(|(a, b)| a == b)
        .count();
    println!("\nFI(6,8) agrees with float32 on {agree}/16 predictions");
    assert_eq!(fi_pjrt, fi_engine,
               "PJRT fake-quant and bit-accurate engine must agree");

    // 6. what that representation costs in hardware (Table 5 model)
    use lop::hw::datapath::{Datapath, N_PE};
    for cfg in [&f32cfg, &fi] {
        let dp = Datapath::synthesize(cfg.kind(0), N_PE);
        println!(
            "{:<10} {:>9.0} ALMs  {:>4} DSPs  {:>7.2} MHz  {:>6.2} W  \
             {:>6.2} Gops/J",
            cfg.name(), dp.alms, dp.dsps, dp.fmax_mhz, dp.power_w,
            dp.gops_per_j
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
