//! Design-space exploration (paper §4.2): the two-pass topological search
//! over per-layer representations, with the hardware cost model as the
//! pass-1 objective and accuracy as the constraint.
//!
//!     cargo run --release --example explore_dse

use anyhow::Result;
use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::{explore, ExploreOpts, Family};
use lop::coordinator::ranges::profile_ranges;
use lop::data::Dataset;
use lop::hw::datapath::{Datapath, ARRIA10, N_PE};
use lop::nn::network::Model;
use lop::nn::spec::NetSpec;
use lop::runtime::ArtifactDir;

fn main() -> Result<()> {
    let art = ArtifactDir::discover()?;
    let model = Model::load(NetSpec::paper_dcnn(), &art.weights_path())?;
    let ds = Dataset::load(&art.dataset_path())?;

    // Table 1 first: the ranges bound the integral/exponent BCIs
    let ranges = profile_ranges(&model, &ds, 1_000, 0);
    println!("WBA ranges (drive the range-determined BCI fields):");
    for r in &ranges {
        let c = r.combined();
        println!("  {:<6} [{:>7.2}, {:>6.2}]", r.layer, c.0, c.1);
    }

    // PJRT accelerates the exact-config evaluations when available;
    // otherwise the bit-accurate engine computes the same accuracies.
    let weights_path = art.weights_path();
    let runner = lop::runtime::runner_or_warn(art);
    let model2 = Model::load(NetSpec::paper_dcnn(), &weights_path)?;
    let mut ev = Evaluator::new(model2, runner, ds, 300, 0);

    let opts = ExploreOpts {
        accuracy_bound: 0.01,
        frac_bci: (5, 10),
        int_headroom: 1,
        families: vec![Family::Fixed, Family::Float],
        second_pass: true,
        ..Default::default()
    };
    println!("\nexploring: bound {:.0}%, frac BCI {:?}, families {:?}",
             opts.accuracy_bound * 100.0, opts.frac_bci, opts.families);
    let res = explore(&mut ev, &ranges, &opts)?;

    println!("\nbaseline (subset) : {:.4}", res.baseline);
    println!("pass-1 (cost-min) : {}  acc {:.4}", res.pass1.name(),
             res.pass1_accuracy);
    println!("pass-2 (recovery) : {}  acc {:.4}", res.chosen.name(),
             res.accuracy);
    println!("distinct configs evaluated: {}", res.evals);
    let cache = ev.plan_cache().stats();
    println!("engine nets cached: {} ({:.2} MiB prepacked weight \
              panels resident; {} prepares / {} hits / {} evictions \
              in the shared plan cache)",
             ev.prepared_nets(),
             ev.panel_bytes() as f64 / (1024.0 * 1024.0),
             cache.prepares, cache.hits, cache.evictions);

    // hardware verdict on the chosen per-layer representations
    println!("\nhardware cost of the chosen per-layer domains:");
    for (li, kind) in res.chosen.kinds().iter().enumerate() {
        let dp = Datapath::synthesize(kind, N_PE);
        let (a, d) = dp.utilization(&ARRIA10);
        println!(
            "  layer {} {:<12} {:>8.0} ALMs ({:>4.1}%)  {:>4} DSPs \
             ({:>4.1}%)  {:>6.2} Gops/J",
            li, kind.name(), dp.alms, a * 100.0, dp.dsps, d * 100.0,
            dp.gops_per_j
        );
    }
    println!("\nexplore_dse OK");
    Ok(())
}
