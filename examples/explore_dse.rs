//! Design-space exploration (paper §4.2, surrogate-guided): profile
//! per-layer quality sensitivity once, score the whole candidate space
//! through the analytic cost model, and only simulate the
//! surrogate-predicted Pareto front through the real evaluator.
//!
//!     cargo run --release --example explore_dse

use anyhow::Result;
use lop::coordinator::eval::Evaluator;
use lop::coordinator::explorer::{Explorer, ExploreOpts, Family};
use lop::coordinator::ranges::profile_ranges;
use lop::data::Dataset;
use lop::hw::datapath::{Datapath, ARRIA10, N_PE};
use lop::nn::network::Model;
use lop::nn::spec::NetSpec;
use lop::runtime::ArtifactDir;

fn main() -> Result<()> {
    let art = ArtifactDir::discover()?;
    let model = Model::load(NetSpec::paper_dcnn(), &art.weights_path())?;
    let ds = Dataset::load(&art.dataset_path())?;

    // Table 1 first: the ranges bound the integral/exponent BCIs
    let ranges = profile_ranges(&model, &ds, 1_000, 0);
    println!("WBA ranges (drive the range-determined BCI fields):");
    for r in &ranges {
        let c = r.combined();
        println!("  {:<6} [{:>7.2}, {:>6.2}]", r.layer, c.0, c.1);
    }

    // PJRT accelerates the exact-config evaluations when available;
    // otherwise the bit-accurate engine computes the same accuracies.
    let weights_path = art.weights_path();
    let runner = lop::runtime::runner_or_warn(art);
    let model2 = Model::load(NetSpec::paper_dcnn(), &weights_path)?;
    let mut ev = Evaluator::new(model2, runner, ds, 300, 0);

    let opts = ExploreOpts {
        accuracy_bound: 0.01,
        frac_bci: (5, 10),
        int_headroom: 1,
        families: vec![Family::Fixed, Family::Float],
        second_pass: true,
        ..Default::default()
    };
    println!("\nexploring: frac BCI {:?}, families {:?}, budget {:.0}%",
             opts.frac_bci, opts.families,
             (1.0 - opts.accuracy_bound) * 100.0);
    let budget_frac = 1.0 - opts.accuracy_bound;
    let front = Explorer::new(NetSpec::paper_dcnn())
        .opts(opts)
        .ranges(ranges)
        .max_sims(8)
        .calibration(64)
        .run(&mut ev)?;
    let baseline = front.baseline_accuracy();

    println!("\nbaseline (subset) : {:.4}", baseline);
    println!("candidate space   : {} configs", front.space());
    println!("full simulations  : {} ({} saved by the surrogate)",
             front.sims(),
             front.space().saturating_sub(front.sims() as u64));
    println!("\npareto front ({} cost model):", front.cost_source());
    println!("  {:<44} {:>8} {:>8} {:>10} {:>8}  origin",
             "config", "acc", "est", "lat(us)", "hw");
    for p in front.points() {
        println!("  {:<44} {:>8.4} {:>8.4} {:>10.1} {:>8.3}  {}",
                 p.repr_map.name(), p.accuracy, p.est_accuracy,
                 p.est_latency / 1_000.0, p.hw_cost,
                 if p.simulated { "simulated" } else { "surrogate" });
    }

    let cache = ev.plan_cache().stats();
    println!("\nengine nets cached: {} ({:.2} MiB prepacked weight \
              panels resident; {} prepares / {} hits / {} evictions \
              in the shared plan cache)",
             ev.prepared_nets(),
             ev.panel_bytes() as f64 / (1024.0 * 1024.0),
             cache.prepares, cache.hits, cache.evictions);

    // hardware verdict on the cheapest config inside the budget
    let budget = baseline * budget_frac;
    match front.best_within(budget) {
        Some(best) => {
            println!("\ncheapest config with accuracy >= {budget:.4}: \
                      {}  acc {:.4}",
                     best.repr_map.name(), best.accuracy);
            println!("hardware cost of its per-layer domains:");
            for (li, kind) in best.repr_map.kinds().iter().enumerate() {
                let dp = Datapath::synthesize(kind, N_PE);
                let (a, d) = dp.utilization(&ARRIA10);
                println!(
                    "  layer {} {:<12} {:>8.0} ALMs ({:>4.1}%)  {:>4} \
                     DSPs ({:>4.1}%)  {:>6.2} Gops/J",
                    li, kind.name(), dp.alms, a * 100.0, dp.dsps,
                    d * 100.0, dp.gops_per_j
                );
            }
        }
        None => println!("\nno front point met accuracy {budget:.4}; \
                          widen the BCIs or loosen the bound"),
    }
    println!("\nexplore_dse OK");
    Ok(())
}
