//! END-TO-END DRIVER (DESIGN.md Fig.-1 row): the full Lop stack serving a
//! real workload — router → per-config dynamic batcher → PJRT worker
//! (exact-arithmetic configs, XLA-compiled AOT artifacts) + bit-accurate
//! engine workers (approximate-multiplier configs) — under an open-loop
//! request stream, reporting latency percentiles, throughput and stream
//! accuracy.  Recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example serve_inference

use anyhow::Result;
use lop::coordinator::batcher::{FailureKind, Outcome};
use lop::coordinator::router::OverloadPolicy;
use lop::coordinator::server::{Server, ServerOpts};
use lop::data::synth;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::runtime::execution_plan;
use lop::util::prng::Rng;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let spec = NetSpec::paper_dcnn();
    let cfg = |s: &str| ReprMap::parse_for(&spec, s).unwrap();
    let configs = vec![
        cfg("float32"),
        cfg("FI(6,8)"),
        cfg("FL(4,9)"),
        cfg("H(6,8,12)"), // engine-backed
    ];
    let names: Vec<String> = configs.iter().map(|c| c.name()).collect();
    // name each config's backend up front: engine configs list the
    // per-layer packed kernels whose weight panels `prepare` will cache
    for c in &configs {
        let plan = execution_plan(c);
        match plan.engine_kernels() {
            Some(kernels) => println!("  {}: engine, kernels {:?} \
                                       (prepacked weight panels)",
                                      c.name(), kernels),
            None => println!("  {}: {:?} (weights resident on device)",
                             c.name(), plan),
        }
    }
    let opts = ServerOpts {
        configs,
        max_batch: 16,
        max_wait: Duration::from_millis(4),
        queue_capacity: 8_192,
        engine_workers: 3,
        engine_gemm_threads: 2,
        plan_cache_bytes: 256 * 1024 * 1024,
        use_pjrt: true,
        // under overload, re-route to the cheapest config with room
        // (the hw-cost ladder) instead of refusing; requests that
        // still queue past 250ms expire with Error(Expired)
        overload: OverloadPolicy::Degrade,
        deadline: Some(Duration::from_millis(250)),
        inject_backend_failures: false,
    };
    let opts_workers = opts.engine_workers;
    let requests = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(2_000usize);
    let rate = 400.0; // offered load, req/s

    println!("configs: {names:?}");
    println!("load: {requests} requests at {rate} req/s (open loop)");

    let server = Server::start(opts)?;
    let metrics = server.metrics.clone();

    // warm up: run one request per config through to force compilation
    let (wtx, wrx) = channel();
    for ci in 0..names.len() {
        server
            .router
            // long explicit deadline overriding the 250ms default:
            // first-touch compilation legitimately takes longer
            .submit(ci, vec![0.0; 784], Some(Duration::from_secs(600)),
                    wtx.clone())
            .expect("warmup submit");
    }
    for _ in 0..names.len() {
        wrx.recv_timeout(Duration::from_secs(120)).expect("warmup");
    }
    println!("warmup complete (executables compiled, weights resident)");

    // open-loop generator
    let (tx, rx) = channel();
    let (images, labels) = synth::generate(512, 777);
    let mut rng = Rng::new(5);
    let t0 = Instant::now();
    let gap = Duration::from_secs_f64(1.0 / rate);
    let mut next = Instant::now();
    let mut rejected = 0usize;
    let mut submitted_cfg = vec![0usize; requests];
    for i in 0..requests {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        next += gap;
        let img_idx = i % 512;
        let img: Vec<f32> = images[img_idx * 784..(img_idx + 1) * 784]
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect();
        let ci = rng.below(names.len() as u64) as usize;
        submitted_cfg[i] = ci;
        if server.router.submit(ci, img, None, tx.clone()).is_err() {
            rejected += 1;
        }
    }
    drop(tx);

    let mut got = 0usize;
    let mut served = 0usize;
    let mut correct = 0usize;
    let (mut shed, mut expired, mut backend) = (0usize, 0usize, 0usize);
    while got + rejected < requests {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(resp) => {
                got += 1;
                match resp.outcome {
                    Outcome::Ok(pred) => {
                        served += 1;
                        // warmup used ids 0..n_cfg; offset stream ids
                        let sid = resp.id as usize - names.len();
                        if pred == labels[sid % 512] as usize {
                            correct += 1;
                        }
                    }
                    Outcome::Error(FailureKind::Shed) => shed += 1,
                    Outcome::Error(FailureKind::Expired) => {
                        expired += 1
                    }
                    Outcome::Error(FailureKind::Backend) => {
                        backend += 1
                    }
                }
            }
            Err(_) => break,
        }
    }
    let wall = t0.elapsed();
    let depths = server.queue_depths();
    let panels = metrics.panels_cached.get();
    let panel_bytes = metrics.panel_bytes.get();
    let cache = server.plan_cache.stats();
    server.shutdown()?;

    println!("\n================ end-to-end results ================");
    println!("panel cache: {panels} weight panels resident, \
              {:.2} MiB (conditioned once at prepare; forwards do \
              zero weight-side packing)",
             panel_bytes as f64 / (1024.0 * 1024.0));
    println!("plan cache : {} prepares across all {} engine workers \
              ({} hits, {} waits coalesced in flight, {} evictions) — \
              one shared Arc<PreparedNet> per config",
             cache.prepares, opts_workers, cache.hits,
             cache.inflight_waits, cache.evictions);
    println!("queue depths at drain: {depths:?}");
    println!("served     : {served} / {requests} (rejected {rejected}, \
              shed {shed}, expired {expired}, backend {backend})");
    println!("throughput : {:.1} req/s (offered {rate})",
             served as f64 / wall.as_secs_f64());
    println!("accuracy   : {:.4} over the mixed-config stream",
             correct as f64 / served.max(1) as f64);
    println!("{}", metrics.summary(wall));
    assert!(served > 0, "server served no requests");
    assert_eq!(got, served + shed + expired + backend,
               "every answered request carries a typed outcome");
    let acc = correct as f64 / served.max(1) as f64;
    assert!(acc > 0.8, "stream accuracy {acc} suspiciously low");
    println!("serve_inference OK");
    Ok(())
}
