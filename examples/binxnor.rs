//! Extending Lop (paper §4.5): define a new data representation — binary
//! 0/1 values whose multiply is overridden to XNOR, as in binarized neural
//! networks — and use it through the unchanged library machinery: the
//! generic GEMM, the network runner and the hardware cost model all accept
//! it like any built-in representation.
//!
//!     cargo run --release --example binxnor

use anyhow::Result;
use lop::approx::arith::ArithKind;
use lop::data::Dataset;
use lop::hw::datapath::{Datapath, N_PE};
use lop::nn::network::Model;
use lop::nn::spec::{NetSpec, ReprMap};
use lop::numeric::{BinXnor, Representation};
use lop::runtime::ArtifactDir;

fn main() -> Result<()> {
    // 1. the representation itself: XNOR == multiplication in {-1, +1}
    println!("XNOR-as-multiply truth table (paper §4.5 code snippet):");
    for a in 0..2u64 {
        for b in 0..2u64 {
            println!(
                "  {} xnor {} = {}   <->   {:+} * {:+} = {:+}",
                a, b, BinXnor::xnor_mul(a, b),
                BinXnor::to_pm1(a) as i32, BinXnor::to_pm1(b) as i32,
                BinXnor::to_pm1(BinXnor::xnor_mul(a, b)) as i32
            );
        }
    }
    let r = BinXnor;
    println!("quantize(0.7) = {:+}, quantize(-0.2) = {:+}, 1 bit/value",
             r.quantize(0.7), r.quantize(-0.2));

    // 2. use it inside the DCNN without redefining convolution: binarize
    //    the *first* conv layer (where binary nets lose least) and keep
    //    the rest at FI(6, 8)
    let art = ArtifactDir::discover()?;
    let spec = NetSpec::paper_dcnn();
    let model = Model::load(spec.clone(), &art.weights_path())?;
    let ds = Dataset::load(&art.dataset_path())?;
    let n = 300.min(ds.test.len());
    let idx: Vec<usize> = (0..n).collect();
    let x = ds.batch(&ds.test, &idx);
    let labels = &ds.test.labels;

    let acc = |cfg: &ReprMap| -> f64 {
        let preds = model.prepare(cfg).predict(&x, 0);
        preds
            .iter()
            .zip(labels.iter())
            .filter(|(p, l)| **p == **l as usize)
            .count() as f64
            / n as f64
    };

    let base = ReprMap::parse_for(&spec, "FI(6,8)").unwrap();
    let bin1 =
        ReprMap::parse_for(&spec, "binxnor|FI(6,8)|FI(6,8)|FI(6,8)")
            .unwrap();
    let binall = ReprMap::uniform_for(&spec, ArithKind::Binary);

    let (a_base, a_bin1, a_binall) = (acc(&base), acc(&bin1), acc(&binall));
    println!("\naccuracy over {n} test images:");
    println!("  FI(6,8) everywhere        : {a_base:.4}");
    println!("  BinXNOR conv1, FI rest    : {a_bin1:.4}");
    println!("  BinXNOR everywhere        : {a_binall:.4}");
    println!("(binarizing everything wrecks a net trained in float — the \
              paper's point is the *mechanism*: multiply is overridden, \
              convolution machinery untouched)");

    // 3. and the hardware story: a 1-bit XNOR PE costs almost nothing
    for k in [ArithKind::parse("FI(6,8)").unwrap(), ArithKind::Binary] {
        let dp = Datapath::synthesize(&k, N_PE);
        println!(
            "  {:<10} {:>9.0} ALMs  {:>4} DSPs  {:>7.1} MHz  {:>6.2} W",
            k.name(), dp.alms, dp.dsps, dp.fmax_mhz, dp.power_w
        );
    }

    assert!(a_bin1 > 0.3, "conv1 binarization should retain signal");
    println!("\nbinxnor OK");
    Ok(())
}
