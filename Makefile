# Lop build entry points.  Tier-1 (hermetic, no Python) is just:
#   cargo build --release && cargo test -q

.PHONY: all test artifacts bench-tables clean-artifacts

all:
	cargo build --release

test:
	cargo test -q

# AOT artifacts consumed by the runtime, integration tests and
# table1/3/4 benches: trained weights, dataset, WBA ranges, golden
# vectors, HLO text modules.  Needs a JAX-capable python3.
artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Hermetic paper-table benches (table5 + kernels need nothing on disk).
bench-tables:
	cargo bench --bench table5_hw
	cargo bench --bench gemm_kernels

clean-artifacts:
	rm -rf artifacts
