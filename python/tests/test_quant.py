"""jnp fake-quant emulation vs the bit-accurate scalar reference.

These are the bit-exactness contracts: quant.py (which runs inside the AOT
artifacts) must agree with bitref.py (which generates the Rust golden
vectors) on every value.  Hypothesis sweeps values and widths.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import bitref
from compile.quant import drum_mul, fake_quant_fi, fake_quant_fl, fi_params

settings.register_profile("lop", max_examples=60, deadline=None)
settings.load_profile("lop")


def _check_fi(xs, i, f):
    scale, maxk = fi_params(i, f)
    got = np.asarray(fake_quant_fi(jnp.asarray(xs, jnp.float32),
                                   jnp.float32(scale), jnp.float32(maxk)))
    want = np.array([bitref.fi_quantize(float(x), i, f) for x in
                     np.asarray(xs, np.float32)], np.float32)
    np.testing.assert_array_equal(got, want)


def _check_fl(xs, e, m):
    got = np.asarray(fake_quant_fl(jnp.asarray(xs, jnp.float32),
                                   jnp.int32(e), jnp.int32(m)))
    want = np.array([bitref.fl_quantize(float(x), e, m) for x in
                     np.asarray(xs, np.float32)], np.float32)
    np.testing.assert_array_equal(got, want)


@given(st.integers(0, 8), st.integers(0, 12),
       st.lists(st.floats(-1e4, 1e4, width=32), min_size=1, max_size=50))
def test_fi_matches_bitref(i, f, xs):
    _check_fi(np.array(xs, np.float32), i, f)


def test_fi_edge_values():
    for i, f in [(4, 8), (6, 8), (0, 7), (8, 0), (11, 11)]:
        maxv = bitref.fi_max(i, f)
        xs = np.array([0.0, -0.0, maxv, -maxv, maxv * 2, -maxv * 2,
                       0.5 / 2 ** f, 1.5 / 2 ** f, -0.5 / 2 ** f,
                       1e-30, -1e-30], np.float32)
        _check_fi(xs, i, f)


def test_fi_tie_rounding_half_away():
    # magnitude ties round away from zero
    _check_fi(np.array([0.5, -0.5, 1.5, -1.5, 2.5], np.float32), 4, 0)
    got = np.asarray(fake_quant_fi(jnp.float32(0.5), jnp.float32(1.0),
                                   jnp.float32(15.0)))
    assert got == 1.0


@given(st.integers(2, 7), st.integers(1, 15),
       st.lists(st.floats(-1e6, 1e6, width=32), min_size=1, max_size=50))
def test_fl_matches_bitref(e, m, xs):
    _check_fl(np.array(xs, np.float32), e, m)


def test_fl_edge_values():
    for e, m in [(4, 9), (4, 8), (5, 10), (2, 2), (7, 15), (4, 1)]:
        mn = bitref.fl_min_normal(e)
        mx = bitref.fl_max(e, m)
        xs = np.array([0.0, -0.0, 1.0, -1.0, mn, mn / 2, mn / 2.0001,
                       mn * 0.50001, -mn / 2, mx, -mx, mx * 4, 1.0 + 2.0 ** -(m + 1),
                       2.0 ** -40, 3.0], np.float32)
        _check_fl(xs, e, m)


def test_fl_rne_ties_to_even():
    # value exactly halfway between two mantissa grid points, even below
    e, m = 4, 2
    x = 1.0 + 2.0 ** -3  # 1.125: between 1.00 (even) and 1.25 -> 1.0
    assert bitref.fl_quantize(x, e, m) == 1.0
    got = float(np.asarray(fake_quant_fl(jnp.float32(x), jnp.int32(e),
                                         jnp.int32(m))))
    assert got == 1.0


@given(st.integers(2, 22), st.integers(2, 16), st.integers(0, 2 ** 22 - 1),
       st.integers(0, 2 ** 22 - 1))
def test_drum_matches_bitref(nbits, k, a, b):
    a &= (1 << nbits) - 1
    b &= (1 << nbits) - 1
    with jax.experimental.enable_x64():
        got = int(drum_mul(jnp.asarray([a]), jnp.asarray([b]), k)[0])
    assert got == bitref.drum_mul(a, b, k)


def test_drum_exact_below_threshold():
    # operands below 2^k are not approximated at all
    for k in (4, 8, 12):
        for a in (0, 1, (1 << k) - 1):
            assert bitref.drum_approx_operand(a, k) == a


def test_quantize_idempotent():
    rng = np.random.default_rng(0)
    xs = rng.normal(0, 5, 200).astype(np.float32)
    for i, f in [(4, 8), (6, 8)]:
        q1 = np.array([bitref.fi_quantize(float(x), i, f) for x in xs])
        q2 = np.array([bitref.fi_quantize(float(x), i, f) for x in q1])
        np.testing.assert_array_equal(q1, q2)
    for e, m in [(4, 9), (5, 10)]:
        q1 = np.array([bitref.fl_quantize(float(x), e, m) for x in xs])
        q2 = np.array([bitref.fl_quantize(float(x), e, m) for x in q1])
        np.testing.assert_array_equal(q1, q2)
