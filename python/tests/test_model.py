"""L2 model tests: Pallas-backed forward vs the lax.conv oracle, shape
contracts, quantization plumbing, and Table-1 range extraction."""

import jax.numpy as jnp
import numpy as np

from compile.model import (activation_ranges, forward, forward_train,
                           im2col, init_params, maxpool2, param_names)
from compile.quant import fi_params


def _params():
    return init_params(seed=3)


def _x(b=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, 1, (b, 28, 28, 1)).astype(np.float32))


def test_param_names_order():
    assert param_names() == [
        "conv1_w", "conv1_b", "conv2_w", "conv2_b",
        "fc1_w", "fc1_b", "fc2_w", "fc2_b",
    ]


def test_shapes_match_paper_fig2():
    p = _params()
    assert p["conv1_w"].shape == (5, 5, 1, 32)
    assert p["conv2_w"].shape == (5, 5, 32, 64)
    assert p["fc1_w"].shape == (3136, 1024)
    assert p["fc2_w"].shape == (1024, 10)
    logits = forward_train(p, _x(3))
    assert logits.shape == (3, 10)


def test_im2col_layout():
    """Patch layout (ky, kx, c) must match rust/src/nn/conv.rs."""
    b, h, w, c = 1, 4, 4, 2
    x = jnp.arange(b * h * w * c, dtype=jnp.float32).reshape(b, h, w, c)
    cols = im2col(x, 3, 3, 1)
    assert cols.shape == (16, 18)
    # center pixel of patch at (y=1, x=1) is x[0,1,1,:] at offset (ky=1,kx=1)
    patch = np.asarray(cols[1 * 4 + 1]).reshape(3, 3, 2)
    np.testing.assert_array_equal(patch[1, 1], np.asarray(x[0, 1, 1]))
    # top-left of patch at (0,0) is zero padding
    patch00 = np.asarray(cols[0]).reshape(3, 3, 2)
    np.testing.assert_array_equal(patch00[0, 0], [0.0, 0.0])


def test_maxpool2():
    x = jnp.asarray(np.arange(16, dtype=np.float32).reshape(1, 4, 4, 1))
    y = np.asarray(maxpool2(x))
    np.testing.assert_array_equal(y[0, :, :, 0], [[5, 7], [13, 15]])


def test_forward_pallas_matches_oracle_f32():
    p = _params()
    x = _x(2)
    got = np.asarray(forward(p, x, "none"))
    want = np.asarray(forward_train(p, x, "none"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_forward_pallas_matches_oracle_fi():
    p = _params()
    x = _x(2, seed=1)
    qs = []
    for i, f in [(5, 8), (6, 8), (6, 8), (6, 8)]:
        qs.extend(fi_params(i, f))
    got = np.asarray(forward(p, x, "fi", [jnp.float32(v) for v in qs]))
    want = np.asarray(forward_train(p, x, "fi",
                                    [jnp.float32(v) for v in qs]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_forward_pallas_matches_oracle_fl():
    p = _params()
    x = _x(2, seed=2)
    qs = []
    for e, m in [(4, 9), (4, 9), (4, 9), (4, 9)]:
        qs.extend((float(e), float(m)))
    got = np.asarray(forward(p, x, "fl", [jnp.float32(v) for v in qs]))
    want = np.asarray(forward_train(p, x, "fl",
                                    [jnp.float32(v) for v in qs]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_quantization_changes_logits():
    """A brutally narrow representation must actually perturb the output
    (guards against fake-quant being silently skipped)."""
    p = _params()
    x = _x(2, seed=3)
    base = np.asarray(forward_train(p, x, "none"))
    qs = []
    for i, f in [(1, 1)] * 4:
        qs.extend(fi_params(i, f))
    coarse = np.asarray(forward_train(p, x, "fi",
                                      [jnp.float32(v) for v in qs]))
    assert not np.allclose(base, coarse, atol=1e-3)


def test_activation_ranges_structure():
    p = _params()
    r = activation_ranges(p, _x(4))
    assert set(r.keys()) == {"conv1", "conv2", "fc1", "fc2"}
    for layer in r.values():
        lo, hi = layer["range"]
        assert lo <= hi
        assert layer["w"][0] <= layer["w"][1]
    # input is non-negative, relu outputs non-negative: conv1 max > 0
    assert r["conv1"]["a"][1] > 0
