"""Quantization-aware retraining (paper question 4): conversion to an
aggressive representation costs accuracy; retraining under the quantized
datapath recovers a meaningful part of it."""

import jax.numpy as jnp
import numpy as np

from compile import qat
from compile.model import init_params
from compile.quant import fi_params
from compile import train as trainer


def test_ste_preserves_gradient_path():
    import jax

    params = init_params(seed=0)
    qscalars = []
    for i, f in [(2, 3)] * 4:
        qscalars.extend(fi_params(i, f))
    qscalars = [jnp.float32(v) for v in qscalars]

    def loss(p):
        # linear functional of the quantized params: its true gradient
        # through the quantizer is 0 a.e., but the STE passes identity,
        # so d(loss)/dp must be exactly 1 for every element
        qp = qat.ste_quant_params(p, qscalars)
        return sum(jnp.sum(v) for v in qp.values())

    g = jax.grad(loss)(params)
    for k, v in g.items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.ones_like(np.asarray(v)),
                                      err_msg=k)


def test_ste_forward_is_quantized():
    params = init_params(seed=1)
    qscalars = []
    for i, f in [(1, 1)] * 4:
        qscalars.extend(fi_params(i, f))
    qp = qat.ste_quant_params(params, [jnp.float32(v) for v in qscalars])
    w = np.asarray(qp["fc1_w"])
    # FI(1,1) grid: multiples of 0.5 clamped at 1.5
    assert np.all(np.abs(w * 2 - np.round(w * 2)) < 1e-6)
    assert np.abs(w).max() <= 1.5 + 1e-6


def test_retraining_recovers_accuracy():
    """Train a small float model, convert to an aggressive FI config
    (accuracy drops), retrain (accuracy recovers)."""
    params, _, _, _ = trainer.train(steps=120, batch=64, n_train=2000,
                                    n_test=400, seed=5, verbose=False)
    cfg = [(1, 3), (2, 3), (3, 3), (6, 3)]  # 3 fractional bits everywhere
    _, hist = qat.retrain(params, cfg, steps=80, n_train=2000,
                          verbose=False)
    drop = hist["float_accuracy_before"] - hist["quantized_accuracy_before"]
    gain = (hist["quantized_accuracy_after"]
            - hist["quantized_accuracy_before"])
    # conversion must actually hurt for the question to be meaningful...
    assert drop > 0.02, f"conversion only cost {drop:.4f}"
    # ...and retraining must recover a meaningful part of the loss
    assert gain > drop * 0.3, (
        f"retraining recovered too little: drop {drop:.4f}, gain {gain:.4f}"
    )
