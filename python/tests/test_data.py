"""Synthetic dataset generator tests: determinism, format round-trip,
class coverage, and basic image sanity."""

import os
import tempfile

import numpy as np

from compile import data


def test_deterministic():
    a_x, a_y = data.generate(50, seed=9)
    b_x, b_y = data.generate(50, seed=9)
    np.testing.assert_array_equal(a_x, b_x)
    np.testing.assert_array_equal(a_y, b_y)


def test_seed_changes_data():
    a_x, _ = data.generate(50, seed=1)
    b_x, _ = data.generate(50, seed=2)
    assert not np.array_equal(a_x, b_x)


def test_shapes_and_dtype():
    x, y = data.generate(20, seed=0)
    assert x.shape == (20, 28, 28) and x.dtype == np.uint8
    assert y.shape == (20,) and y.dtype == np.uint8
    assert y.max() <= 9


def test_all_classes_present():
    _, y = data.generate(500, seed=4)
    assert set(np.unique(y)) == set(range(10))


def test_images_have_ink():
    x, _ = data.generate(100, seed=5)
    frac_on = (x > 64).mean(axis=(1, 2))
    assert (frac_on > 0.01).all(), "some image is (almost) blank"
    assert (frac_on < 0.7).all(), "some image is mostly ink"


def test_roundtrip_bin():
    trx, try_ = data.generate(30, seed=0)
    tex, tey = data.generate(10, seed=1)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "ds.bin")
        data.write_dataset_bin(p, trx, try_, tex, tey)
        rx, ry, sx, sy = data.load_dataset_bin(p)
    np.testing.assert_array_equal(rx, trx)
    np.testing.assert_array_equal(ry, try_)
    np.testing.assert_array_equal(sx, tex)
    np.testing.assert_array_equal(sy, tey)


def test_classes_visually_distinct():
    """Mean images of different classes should differ substantially —
    otherwise the classification task is degenerate."""
    x, y = data.generate(400, seed=6)
    xf = data.to_float(x)
    means = np.stack([xf[y == c].mean(axis=0) for c in range(10)])
    for a in range(10):
        for b in range(a + 1, 10):
            d = np.abs(means[a] - means[b]).mean()
            assert d > 0.01, f"classes {a} and {b} look identical"
