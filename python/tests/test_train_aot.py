"""Trainer + AOT exporter smoke tests (fast variants — the real run happens
in `make artifacts`)."""

import os
import struct
import tempfile

import jax.numpy as jnp
import numpy as np

from compile import bitref, data
from compile import train as trainer
from compile.aot import lower_forward, write_golden_vectors
from compile.model import forward_train, init_params, param_names


def test_loss_decreases_quickly():
    params, _, _, acc = trainer.train(steps=30, batch=32, n_train=300,
                                      n_test=200, seed=3, verbose=False)
    # 30 steps on an easy synthetic task: must beat chance comfortably
    assert acc > 0.3


def test_weights_bin_format():
    params = init_params(seed=0)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "w.bin")
        trainer.save_weights_bin(p, params)
        with open(p, "rb") as fh:
            assert fh.read(4) == b"LOPW"
            ver, n = struct.unpack("<II", fh.read(8))
            assert ver == 1 and n == 8
            names = []
            for _ in range(n):
                ln = struct.unpack("<I", fh.read(4))[0]
                name = fh.read(ln).decode()
                names.append(name)
                nd = struct.unpack("<I", fh.read(4))[0]
                dims = struct.unpack(f"<{nd}I", fh.read(4 * nd))
                count = int(np.prod(dims))
                raw = fh.read(4 * count)
                arr = np.frombuffer(raw, np.float32).reshape(dims)
                np.testing.assert_array_equal(arr, np.asarray(params[name]))
            assert names == param_names()


def test_adam_moves_params():
    params = init_params(seed=0)
    st = trainer.adam_init(params)
    grads = {k: jnp.ones_like(v) for k, v in params.items()}
    new, st2 = trainer.adam_update(params, grads, st, lr=1e-2)
    assert int(st2["t"]) == 1
    assert not np.allclose(np.asarray(new["fc2_w"]),
                           np.asarray(params["fc2_w"]))


def test_golden_vectors_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        inv = write_golden_vectors(d, seed=1)
        assert set(inv) == {"fi_quant", "fl_quant", "drum", "cfpu",
                            "h_mul", "mitchell", "truncated", "loa",
                            "ssm"}
        # spot-check fi_quant records against bitref
        with open(os.path.join(d, "fi_quant.bin"), "rb") as fh:
            assert fh.read(4) == b"LOPG"
            ver, count, recsz = struct.unpack("<III", fh.read(12))
            assert ver == 1 and count == inv["fi_quant"] and recsz == 16
            for _ in range(50):
                x, i, f, y = struct.unpack("<fIIf", fh.read(16))
                assert y == np.float32(bitref.fi_quantize(x, i, f))


def test_lower_forward_produces_hlo_text():
    params = init_params(seed=0)
    text = lower_forward(params, batch=1, mode="none")
    assert "HloModule" in text
    assert "parameter(0)" in text
    # 9 parameters: x + 8 weight tensors
    assert "parameter(8)" in text and "parameter(9)" not in text
    text_fi = lower_forward(params, batch=1, mode="fi")
    assert "parameter(16)" in text_fi  # + 8 quant scalars
