"""Property tests on the bit-accurate reference itself (bitref.py).

bitref is the root of the cross-language correctness chain, so its own
invariants get checked independently: grid membership, monotonicity,
encode/decode round trips, approximation error bounds from the source
papers.
"""

import math

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import bitref

settings.register_profile("lop", max_examples=80, deadline=None)
settings.load_profile("lop")

# ---------------------------------------------------------------------------
# fixed point
# ---------------------------------------------------------------------------


@given(st.floats(-1e5, 1e5), st.integers(0, 10), st.integers(0, 12))
def test_fi_on_grid(x, i, f):
    q = bitref.fi_quantize(x, i, f)
    k = q * 2 ** f
    assert k == int(k), "quantized value is not on the FI grid"
    assert abs(q) <= bitref.fi_max(i, f)


@given(st.floats(-100, 100), st.floats(-100, 100), st.integers(0, 8),
       st.integers(0, 10))
def test_fi_monotone(a, b, i, f):
    if a > b:
        a, b = b, a
    assert bitref.fi_quantize(a, i, f) <= bitref.fi_quantize(b, i, f)


@given(st.floats(-300, 300), st.integers(0, 8), st.integers(0, 10))
def test_fi_encode_decode_roundtrip(x, i, f):
    q = bitref.fi_quantize(x, i, f)
    assert bitref.fi_decode(bitref.fi_encode(x, i, f), i, f) == q


@given(st.floats(-15, 15), st.integers(1, 8), st.integers(1, 10))
def test_fi_error_bound(x, i, f):
    q = bitref.fi_quantize(x, i, f)
    if abs(x) <= bitref.fi_max(i, f):
        assert abs(q - x) <= 0.5 / 2 ** f + 1e-12


# ---------------------------------------------------------------------------
# floating point
# ---------------------------------------------------------------------------


@given(st.floats(-1e6, 1e6), st.integers(2, 7), st.integers(1, 16))
def test_fl_quantize_idempotent(x, e, m):
    q = bitref.fl_quantize(x, e, m)
    assert bitref.fl_quantize(q, e, m) == q


@given(st.floats(-1e4, 1e4), st.floats(-1e4, 1e4), st.integers(2, 7),
       st.integers(1, 12))
def test_fl_monotone(a, b, e, m):
    if a > b:
        a, b = b, a
    assert bitref.fl_quantize(a, e, m) <= bitref.fl_quantize(b, e, m)


@given(st.floats(-1e5, 1e5), st.integers(2, 7), st.integers(1, 14))
def test_fl_encode_decode_roundtrip(x, e, m):
    q = bitref.fl_quantize(x, e, m)
    assert bitref.fl_decode(bitref.fl_encode(x, e, m), e, m) == q


@given(st.integers(2, 7), st.integers(1, 14),
       st.floats(1e-3, 1e3))
def test_fl_relative_error_bound(e, m, x):
    """Inside the normal range, relative error <= 2^-(m+1)."""
    q = bitref.fl_quantize(x, e, m)
    if bitref.fl_min_normal(e) <= x <= bitref.fl_max(e, m):
        assert abs(q - x) / x <= 2.0 ** -(m + 1) + 1e-12


def test_fl_specials():
    assert bitref.fl_quantize(0.0, 4, 9) == 0.0
    assert bitref.fl_quantize(-0.0, 4, 9) == 0.0
    mx = bitref.fl_max(4, 9)
    assert bitref.fl_quantize(1e30, 4, 9) == mx
    assert bitref.fl_quantize(-1e30, 4, 9) == -mx
    mn = bitref.fl_min_normal(4)
    assert bitref.fl_quantize(mn * 0.49, 4, 9) == 0.0
    assert bitref.fl_quantize(mn * 0.51, 4, 9) == mn
    assert bitref.fl_quantize(mn * 0.5, 4, 9) == mn  # tie -> min normal


# ---------------------------------------------------------------------------
# DRUM
# ---------------------------------------------------------------------------


@given(st.integers(0, 2 ** 30 - 1), st.integers(0, 2 ** 30 - 1),
       st.integers(2, 20))
def test_drum_error_bound(a, b, k):
    """DRUM's worst-case relative error is bounded: each operand is off
    by at most a factor (1 + 2^-(k-1)), so the product by
    (1 + 2^-(k-1))^2 - 1; the product of zero is zero."""
    exact = a * b
    approx = bitref.drum_mul(a, b, k)
    if exact == 0:
        assert approx == 0
    else:
        rel = abs(approx - exact) / exact
        assert rel <= (1.0 + 2.0 ** -(k - 1)) ** 2 - 1.0 + 1e-12


@given(st.integers(0, 2 ** 24 - 1), st.integers(2, 24))
def test_drum_operand_preserves_msbs(a, k):
    aa = bitref.drum_approx_operand(a, k)
    assert aa.bit_length() == a.bit_length()
    if a >= (1 << k):
        sh = a.bit_length() - k
        assert (aa >> sh) >> 1 == (a >> sh) >> 1  # top k-1 bits identical
        assert aa & ((1 << sh) - 1) == 0 or sh == 0


@given(st.integers(0, 255), st.integers(0, 255))
def test_drum_commutative(a, b):
    assert bitref.drum_mul(a, b, 6) == bitref.drum_mul(b, a, 6)


# ---------------------------------------------------------------------------
# CFPU
# ---------------------------------------------------------------------------


@given(st.floats(0.01, 100.0), st.integers(0, 6))
def test_cfpu_power_of_two_exact(x, p):
    """Multiplying by an exact power of two must be error-free (the
    mantissa-skip path): that is CFPU's design point."""
    e, m, w = 4, 9, 3
    xq = bitref.fl_quantize(x, e, m)
    y = float(2 ** p)
    got = bitref.cfpu_mul(xq, y, e, m, w)
    want = bitref.fl_quantize(xq * y, e, m)
    assert got == want


@given(st.floats(-50, 50), st.floats(-50, 50))
def test_cfpu_sign_correct(x, y):
    got = bitref.cfpu_mul(x, y, 4, 9, 3)
    if got != 0.0:
        assert (got > 0) == ((x > 0) == (y > 0))


@given(st.floats(0.1, 10), st.floats(0.1, 10), st.integers(1, 4))
def test_cfpu_error_bound(x, y, w):
    """Approximate path error is bounded by the discarded mantissa:
    relative error < 2^-w (plus representation rounding)."""
    e, m = 5, 10
    got = bitref.cfpu_mul(x, y, e, m, w)
    exact = bitref.fl_quantize(bitref.fl_quantize(x, e, m)
                               * bitref.fl_quantize(y, e, m), e, m)
    if exact != 0:
        assert abs(got - exact) / abs(exact) <= 2.0 ** -w + 2.0 ** -(m - 1)


def test_cfpu_large_w_is_exact():
    """With w > m the top-bits check can never pass -> exact fallback."""
    rng = np.random.default_rng(0)
    for _ in range(100):
        x, y = rng.normal(0, 5, 2)
        got = bitref.cfpu_mul(float(x), float(y), 4, 9, 10)
        want = bitref.fl_quantize(
            bitref.fl_quantize(float(x), 4, 9)
            * bitref.fl_quantize(float(y), 4, 9), 4, 9)
        assert got == want


# ---------------------------------------------------------------------------
# Mitchell / truncated / LOA
# ---------------------------------------------------------------------------


@given(st.integers(1, 2 ** 16 - 1), st.integers(1, 2 ** 16 - 1))
def test_mitchell_error_bound(a, b):
    """Mitchell's classic worst-case underestimate is ~11.1%."""
    exact = a * b
    approx = bitref.mitchell_mul(a, b, 16)
    assert approx <= exact + max(4, exact // 8)
    assert approx >= exact * 0.885 - 4


def test_mitchell_powers_of_two_exact():
    for ta in range(0, 12):
        for tb in range(0, 12):
            a, b = 1 << ta, 1 << tb
            assert bitref.mitchell_mul(a, b, 16) == a * b


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1))
def test_truncated_keep_all_exact(a, b):
    assert bitref.truncated_mul(a, b, 16, 16) == a * b


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1),
       st.integers(1, 15))
def test_truncated_error_bound(a, b, keep):
    exact = a * b
    approx = bitref.truncated_mul(a, b, 16, keep)
    cut = 16 - keep
    # dropped columns carry at most n * 2^cut weight; compensation halves it
    assert abs(approx - exact) <= 16 * (1 << cut)


@given(st.integers(0, 2 ** 20 - 1), st.integers(0, 2 ** 20 - 1),
       st.integers(0, 12))
def test_loa_error_bound(a, b, l):
    exact = a + b
    approx = bitref.loa_add(a, b, l)
    assert abs(approx - exact) < (1 << max(l, 1))
    if l == 0:
        assert approx == exact


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 12))
def test_loa_add_zero(a, l):
    assert bitref.loa_add(a, 0, l) == a


# ---------------------------------------------------------------------------
# SSM
# ---------------------------------------------------------------------------


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1),
       st.integers(8, 16))
def test_ssm_error_bound(a, b, n):
    exact = a * b
    approx = bitref.ssm_mul(a, b, 16, n)
    assert approx <= exact, "SSM must never overestimate"
    # each operand drops < 2^(w-n); error <= da*b + db*a
    drop = 2 ** (16 - n)
    assert exact - approx <= drop * (a + b)


@given(st.integers(0, 2 ** 8 - 1), st.integers(0, 2 ** 8 - 1))
def test_ssm_small_operands_exact(a, b):
    assert bitref.ssm_mul(a, b, 16, 8) == a * b
