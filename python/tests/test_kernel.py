"""pytest: Pallas kernel vs the pure-jnp oracle — the CORE L1 correctness
signal.  Hypothesis sweeps shapes and quantization widths."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import qmatmul, qmatmul_ref
from compile.quant import fi_params

settings.register_profile("lop", max_examples=25, deadline=None)
settings.load_profile("lop")


def _rand(rng, m, k, n):
    x = rng.normal(0, 2, (m, k)).astype(np.float32)
    w = rng.normal(0, 1, (k, n)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


@given(st.integers(1, 200), st.integers(1, 64), st.integers(1, 150),
       st.integers(0, 2 ** 32 - 1))
def test_qmatmul_none_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k, n)
    got = qmatmul(x, w, "none")
    want = qmatmul_ref(x, w, "none")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@given(st.integers(1, 140), st.integers(1, 50), st.integers(1, 140),
       st.integers(2, 8), st.integers(2, 12), st.integers(0, 2 ** 32 - 1))
def test_qmatmul_fi_matches_ref(m, k, n, i, f, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k, n)
    scale, maxk = fi_params(i, f)
    got = qmatmul(x, w, "fi", scale, maxk)
    want = qmatmul_ref(x, w, "fi", jnp.float32(scale), jnp.float32(maxk))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@given(st.integers(1, 140), st.integers(1, 50), st.integers(1, 140),
       st.integers(2, 7), st.integers(1, 15), st.integers(0, 2 ** 32 - 1))
def test_qmatmul_fl_matches_ref(m, k, n, e, mm, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, k, n)
    got = qmatmul(x, w, "fl", float(e), float(mm))
    want = qmatmul_ref(x, w, "fl", e, mm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_qmatmul_tile_boundaries():
    """Shapes straddling the 128-tile boundaries of the BlockSpec."""
    rng = np.random.default_rng(0)
    for m, k, n in [(127, 25, 32), (128, 25, 32), (129, 25, 32),
                    (256, 3136, 1024), (1, 1, 1), (1, 3136, 10)]:
        x, w = _rand(rng, m, k, n)
        got = qmatmul(x, w, "none")
        want = qmatmul_ref(x, w, "none")
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


def test_qmatmul_quantizes_x_not_w():
    """The kernel snaps x onto the lattice; w passes through untouched
    (weights are pre-quantized on the Rust side)."""
    x = jnp.asarray([[0.3]], jnp.float32)       # not on FI(2,1) grid
    w = jnp.asarray([[0.3]], jnp.float32)
    scale, maxk = fi_params(2, 1)
    got = float(qmatmul(x, w, "fi", scale, maxk)[0, 0])
    # x -> 0.5 (round .6 half away), w stays 0.3
    np.testing.assert_allclose(got, 0.5 * 0.3, rtol=1e-6)


def test_pick_bm_vmem_budget():
    """Adaptive M-tile must stay 128-aligned and inside the x-tile VMEM
    budget for every layer shape in the network (and generally)."""
    from compile.kernels.qmatmul import X_TILE_BYTES, pick_bm

    shapes = [(64 * 784, 25), (64 * 196, 800), (64, 3136), (64, 1024),
              (1, 25), (100_000, 3136), (7, 7)]
    for m, k in shapes:
        bm = pick_bm(m, k)
        assert bm % 128 == 0
        assert bm >= 128
        # budget holds whenever the budget allows >= one 128-row tile
        if k * 4 * 128 <= X_TILE_BYTES:
            assert bm * k * 4 <= max(X_TILE_BYTES, 128 * k * 4), (m, k, bm)
        # grid stays coarse: at most ~16 rows unless the budget caps it
        rows = -(-m // bm)
        assert rows <= 17 or bm * k * 4 > X_TILE_BYTES - k * 4 * 128, \
            (m, k, bm, rows)


def test_qmatmul_tall_tiles_still_correct():
    """Shapes that trigger the tall-tile path (small K, big M)."""
    rng = np.random.default_rng(3)
    m, k, n = 2000, 25, 32
    x = jnp.asarray(rng.normal(0, 1, (m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 1, (k, n)).astype(np.float32))
    got = qmatmul(x, w, "none")
    want = qmatmul_ref(x, w, "none")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
