"""jnp fake-quantization emulation of Lop's data representations.

These functions run inside the L2 JAX model (and the L1 Pallas kernel) and
are *bit-exact* against the scalar reference in ``bitref.py`` — pytest
enforces this (``python/tests/test_quant.py``).  Widths are runtime scalars
so a single AOT-lowered HLO artifact serves every FI / FL configuration:
the Rust coordinator feeds the widths as ordinary parameters.

Precision notes (why f32 arithmetic is exact here):
  * FI: ``|x| * 2^f`` is a power-of-two scaling (exact); ``mag + 0.5`` is
    exact while i+f <= 22 because both operands are multiples of the ulp.
    BCIs are restricted to i+f <= 22 (coordinator enforces the same bound).
  * FL: rounding happens directly on the f32 bit pattern, so it is RNE on
    the true significand; exponent clamping uses integer exponent fields.
    BCIs are restricted to e <= 7 so min/max normals stay inside f32 range.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def fake_quant_fi(x: jnp.ndarray, scale: jnp.ndarray,
                  maxk: jnp.ndarray) -> jnp.ndarray:
    """Quantize to FI(i, f): ``scale = 2^f``, ``maxk = 2^(i+f) - 1``.

    Round-half-away-from-zero on the magnitude, saturation at
    ``maxk / scale`` — matches ``bitref.fi_quantize`` bit-for-bit.
    """
    mag = jnp.abs(x) * scale
    k = jnp.floor(mag + 0.5)
    k = jnp.minimum(k, maxk)
    return jnp.sign(x) * (k / scale)


def fi_params(i: int, f: int) -> tuple[float, float]:
    """Scalar parameters fed to ``fake_quant_fi`` for a given FI(i, f)."""
    return float(2 ** f), float(2 ** (i + f) - 1)


def fake_quant_fl(x: jnp.ndarray, e_bits: jnp.ndarray,
                  m_bits: jnp.ndarray) -> jnp.ndarray:
    """Quantize to FL(e, m) — matches ``bitref.fl_quantize`` bit-for-bit.

    ``e_bits`` / ``m_bits`` are i32 scalars (runtime parameters).  Semantics:
    RNE mantissa rounding, saturate to the max finite value, magnitudes
    below the smallest normal round to the nearer of {0, min_normal} (ties
    to min_normal), exponent field 0 reserved for zero, no inf/nan.
    """
    e_bits = e_bits.astype(jnp.int32)
    m_bits = m_bits.astype(jnp.int32)
    bits = lax.bitcast_convert_type(x, jnp.int32)
    sign = bits & jnp.int32(-0x80000000)
    comb = bits & jnp.int32(0x7FFFFFFF)

    shift = jnp.int32(23) - m_bits
    one = jnp.int32(1)
    half = (one << (shift - one)) - one
    tie = (comb >> shift) & one
    comb2 = comb + half + tie
    comb2 = comb2 & ~((one << shift) - one)

    bias = (one << (e_bits - one)) - one
    emin = one - bias
    emax = ((one << e_bits) - one) - bias

    e_rounded = (comb2 >> jnp.int32(23)) - jnp.int32(127)
    y = lax.bitcast_convert_type(comb2 | sign, jnp.float32)

    # Build min-normal and max-finite by bit construction — XLA CPU's exp2
    # is inexact even at integer arguments, which would corrupt the
    # threshold comparisons below.
    minn = lax.bitcast_convert_type((emin + jnp.int32(127)) << jnp.int32(23),
                                    jnp.float32)
    man_mask = jnp.int32(0x7FFFFF) & ~((one << shift) - one)
    maxv = lax.bitcast_convert_type(
        ((emax + jnp.int32(127)) << jnp.int32(23)) | man_mask, jnp.float32)

    sgn = jnp.where(bits < 0, -1.0, 1.0).astype(jnp.float32)
    a = jnp.abs(x)

    y = jnp.where(e_rounded > emax, sgn * maxv, y)
    sub = sgn * jnp.where(a * 2.0 >= minn, minn, 0.0)
    y = jnp.where(e_rounded < emin, sub, y)
    # f32 subnormal inputs have exponent field 0; they flush via the branch
    # above (e_rounded = -127 < emin always since emin >= -63 for e<=7).
    return jnp.where(x == 0.0, 0.0, y)


# ---------------------------------------------------------------------------
# DRUM(k) emulation on integer arrays (used by pytest cross-checks; the
# full-network approximate-multiplier path runs on the Rust engine).
# ---------------------------------------------------------------------------


def drum_approx_operand(a: jnp.ndarray, k: int) -> jnp.ndarray:
    """Vectorized ``bitref.drum_approx_operand`` for non-negative int32."""
    a = a.astype(jnp.int32)
    af = a.astype(jnp.float32)
    # exponent field of the f32 representation = floor(log2(a)) for a>0;
    # exact because a < 2^24 converts to f32 without rounding in our BCIs.
    t = (lax.bitcast_convert_type(af, jnp.int32) >> jnp.int32(23)) \
        - jnp.int32(127)
    sh = jnp.maximum(t - jnp.int32(k - 1), 0)
    approx = ((a >> sh) | jnp.int32(1)) << sh
    return jnp.where(a < jnp.int32(1 << k), a, approx)


def drum_mul(a: jnp.ndarray, b: jnp.ndarray, k: int) -> jnp.ndarray:
    """DRUM(k) product (int64 — enable jax_enable_x64 before tracing)."""
    aa = drum_approx_operand(a, k).astype(jnp.int64)
    bb = drum_approx_operand(b, k).astype(jnp.int64)
    return aa * bb
