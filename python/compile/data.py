"""Synthetic handwritten-digit dataset (MNIST substitute).

The paper evaluates Lop on MNIST, which is used purely as a *quality probe*
for data-representation choices.  This environment has no network access, so
we procedurally generate a deterministic 10-class 28x28 grayscale digit set:
a 5x7 bitmap glyph per class, rendered through a random affine transform
(rotation / scale / shear / translation), stroke dilation, blur, contrast
jitter and additive noise.  The accuracy-vs-bit-width cliffs the paper
studies are a property of the trained network, not of MNIST itself; see
DESIGN.md section 3 (Substitutions).

Pixels are quantized to u8 before use so the dataset is bit-identical when
re-read from ``artifacts/dataset.bin`` by the Rust side.
"""

from __future__ import annotations

import numpy as np

H = W = 28

# Classic 5x7 dot-matrix font, rows top->bottom, '#' = on.
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_array(d: int) -> np.ndarray:
    rows = _GLYPHS[d]
    return np.array([[1.0 if c == "1" else 0.0 for c in r] for r in rows],
                    dtype=np.float32)


def _render_one(d: int, rng: np.random.Generator) -> np.ndarray:
    """Render digit ``d`` as a 28x28 float image in [0, 1]."""
    glyph = _glyph_array(d)  # 7x5
    # Optional stroke dilation (thicker pen).
    if rng.random() < 0.5:
        g = glyph
        pad = np.zeros((9, 7), dtype=np.float32)
        pad[1:8, 1:6] = g
        dil = np.maximum.reduce([
            pad[1:8, 1:6],
            pad[0:7, 1:6], pad[2:9, 1:6],
            pad[1:8, 0:5], pad[1:8, 2:7],
        ])
        glyph = np.clip(dil, 0.0, 1.0)

    # Random affine parameters.
    ang = rng.uniform(-0.25, 0.25)          # radians, ~±14°
    scale = rng.uniform(0.75, 1.10)
    shear = rng.uniform(-0.25, 0.25)
    tx = rng.uniform(-2.5, 2.5)
    ty = rng.uniform(-2.5, 2.5)

    # Glyph cell size in output pixels (before affine).
    cell_h = 20.0 / 7.0 * scale
    cell_w = 14.0 / 5.0 * scale

    ca, sa = np.cos(ang), np.sin(ang)
    # forward map: out = R @ S @ (glyph coords) + center; we sample inverse.
    cy, cx = H / 2.0 + ty, W / 2.0 + tx

    ys, xs = np.mgrid[0:H, 0:W].astype(np.float32)
    # translate to center
    u = xs - cx
    v = ys - cy
    # inverse rotation
    ur = ca * u + sa * v
    vr = -sa * u + ca * v
    # inverse shear (x-shear)
    ur = ur - shear * vr
    # to glyph coordinates (center of glyph is (3.5, 2.5) cells)
    gx = ur / cell_w + 2.5
    gy = vr / cell_h + 3.5

    # Bilinear sample from the 7x5 glyph (zero outside).
    x0 = np.floor(gx).astype(np.int32)
    y0 = np.floor(gy).astype(np.int32)
    fx = gx - x0
    fy = gy - y0

    def at(yy: np.ndarray, xx: np.ndarray) -> np.ndarray:
        ok = (yy >= 0) & (yy < 7) & (xx >= 0) & (xx < 5)
        yc = np.clip(yy, 0, 6)
        xc = np.clip(xx, 0, 4)
        return np.where(ok, glyph[yc, xc], 0.0)

    img = ((1 - fy) * (1 - fx) * at(y0, x0)
           + (1 - fy) * fx * at(y0, x0 + 1)
           + fy * (1 - fx) * at(y0 + 1, x0)
           + fy * fx * at(y0 + 1, x0 + 1))

    # 3x3 box blur (cheap, separable would be overkill at 28x28).
    padded = np.zeros((H + 2, W + 2), dtype=np.float32)
    padded[1:-1, 1:-1] = img
    img = (
        padded[0:-2, 0:-2] + padded[0:-2, 1:-1] + padded[0:-2, 2:]
        + padded[1:-1, 0:-2] + padded[1:-1, 1:-1] * 2.0 + padded[1:-1, 2:]
        + padded[2:, 0:-2] + padded[2:, 1:-1] + padded[2:, 2:]
    ) / 10.0

    # Contrast jitter + additive noise.
    gain = rng.uniform(0.85, 1.25)
    img = np.clip(img * gain, 0.0, 1.0)
    img = img + rng.normal(0.0, 0.03, size=img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` images; returns (images u8 [n,28,28], labels u8 [n])."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.uint8)
    imgs = np.empty((n, H, W), dtype=np.uint8)
    for k in range(n):
        f = _render_one(int(labels[k]), rng)
        imgs[k] = np.round(f * 255.0).astype(np.uint8)
    return imgs, labels


def to_float(imgs_u8: np.ndarray) -> np.ndarray:
    """u8 images -> float32 in [0,1] (the canonical network input)."""
    return imgs_u8.astype(np.float32) / 255.0


def write_dataset_bin(path: str, train_x: np.ndarray, train_y: np.ndarray,
                      test_x: np.ndarray, test_y: np.ndarray) -> None:
    """Serialize to the LOPD binary format read by rust/src/data/loader.rs."""
    import struct

    with open(path, "wb") as fh:
        fh.write(b"LOPD")
        fh.write(struct.pack("<IIIII", 1, train_x.shape[0], test_x.shape[0],
                             H, W))
        fh.write(train_x.astype(np.uint8).tobytes())
        fh.write(train_y.astype(np.uint8).tobytes())
        fh.write(test_x.astype(np.uint8).tobytes())
        fh.write(test_y.astype(np.uint8).tobytes())


def load_dataset_bin(path: str):
    """Read the LOPD format back (used by tests for round-trip checks)."""
    import struct

    with open(path, "rb") as fh:
        magic = fh.read(4)
        assert magic == b"LOPD", f"bad magic {magic!r}"
        ver, ntr, nte, h, w = struct.unpack("<IIIII", fh.read(20))
        assert ver == 1 and h == H and w == W
        trx = np.frombuffer(fh.read(ntr * h * w), dtype=np.uint8)
        trx = trx.reshape(ntr, h, w)
        try_ = np.frombuffer(fh.read(ntr), dtype=np.uint8)
        tex = np.frombuffer(fh.read(nte * h * w), dtype=np.uint8)
        tex = tex.reshape(nte, h, w)
        tey = np.frombuffer(fh.read(nte), dtype=np.uint8)
    return trx, try_, tex, tey
