"""Bit-accurate pure-Python reference for Lop's numeric formats and
approximate arithmetic units.

This module is the *single source of truth* for arithmetic semantics:

  * the jnp fake-quant emulation (``quant.py``) is tested against it in
    pytest, and
  * the Rust implementations (``rust/src/numeric``, ``rust/src/approx``) are
    tested against golden vectors generated from it (``aot.py`` writes
    ``artifacts/golden/*.bin``).

Formats (paper Table 2):
  FI(i, f)    sign-magnitude fixed point, i integral + f fractional bits.
  FL(e, m)    float with e exponent bits, m mantissa bits, implied leading 1,
              IEEE-like bias, exponent field 0 reserved for zero
              (subnormals flushed), no inf/nan (top exponent is ordinary).
  H(i, f, t)  FI(i, f) with the DRUM(t) approximate multiplier
              [Hashemi et al., ICCAD'15].
  I(e, m)     FL(e, m) with the CFPU approximate multiplier
              [Imani et al., DAC'17].

Everything here is deliberately scalar and simple — clarity over speed.
"""

from __future__ import annotations

import math

# ---------------------------------------------------------------------------
# Fixed point FI(i, f) — sign magnitude
# ---------------------------------------------------------------------------


def fi_max(i: int, f: int) -> float:
    """Largest representable magnitude: 2^i - 2^-f."""
    return (2 ** (i + f) - 1) / float(2 ** f)


def fi_quantize(x: float, i: int, f: int) -> float:
    """Round ``x`` to the nearest FI(i, f) value.

    Rounding is round-half-away-from-zero on the magnitude (matches a simple
    hardware round-and-saturate unit); magnitudes saturate at fi_max.
    """
    scale = float(2 ** f)
    maxk = 2 ** (i + f) - 1
    mag = abs(x) * scale
    k = math.floor(mag + 0.5)
    if k > maxk:
        k = maxk
    v = k / scale
    return -v if (x < 0 and v != 0.0) else v


def fi_encode(x: float, i: int, f: int) -> int:
    """Encode to the (1+i+f)-bit sign-magnitude integer pattern."""
    scale = float(2 ** f)
    maxk = 2 ** (i + f) - 1
    mag = abs(x) * scale
    k = min(math.floor(mag + 0.5), maxk)
    sign = 1 if (x < 0 and k != 0) else 0
    return (sign << (i + f)) | k


def fi_decode(bits: int, i: int, f: int) -> float:
    mask = (1 << (i + f)) - 1
    k = bits & mask
    sign = (bits >> (i + f)) & 1
    v = k / float(2 ** f)
    return -v if sign else v


# ---------------------------------------------------------------------------
# Floating point FL(e, m)
# ---------------------------------------------------------------------------


def fl_bias(e: int) -> int:
    return 2 ** (e - 1) - 1


def fl_emin(e: int) -> int:
    # Exponent field 0 is reserved for zero -> smallest normal has field 1.
    return 1 - fl_bias(e)


def fl_emax(e: int) -> int:
    # No inf/nan: the top exponent field encodes an ordinary value.
    return (2 ** e - 1) - fl_bias(e)


def fl_max(e: int, m: int) -> float:
    return (2.0 - 2.0 ** (-m)) * (2.0 ** fl_emax(e))


def fl_min_normal(e: int) -> float:
    return 2.0 ** fl_emin(e)


def _round_half_even_int(x: float) -> int:
    lo = math.floor(x)
    frac = x - lo
    if frac > 0.5:
        return lo + 1
    if frac < 0.5:
        return lo
    return lo + (lo & 1)


def fl_quantize(x: float, e: int, m: int) -> float:
    """Round ``x`` to the nearest FL(e, m) value.

    Mantissa rounding is round-half-to-even; overflow saturates to fl_max;
    values whose rounded magnitude is below the smallest normal round to
    the nearer of {0, min_normal} (ties to min_normal); -0 normalizes to 0.

    Requires m >= 1: a 0-bit mantissa degenerates into the logarithmic
    representation, whose tie-breaking has no mantissa parity to round to.
    """
    assert m >= 1, "FL requires at least one mantissa bit (see docstring)"
    if x == 0.0 or x != x:  # zero (or nan guard: treat as 0 -- no nan format)
        return 0.0
    sign = -1.0 if x < 0 else 1.0
    a = abs(x)
    eu = math.floor(math.log2(a))
    # Guard logarithm edge cases: ensure 1 <= sig < 2.
    sig = a / (2.0 ** eu)
    if sig >= 2.0:
        eu += 1
        sig /= 2.0
    elif sig < 1.0:
        eu -= 1
        sig *= 2.0
    k = _round_half_even_int(sig * (2 ** m))
    if k == 2 ** (m + 1):
        k = 2 ** m
        eu += 1
    y = (k / float(2 ** m)) * (2.0 ** eu)

    if y > fl_max(e, m):
        return sign * fl_max(e, m)
    mn = fl_min_normal(e)
    if y < mn:
        # round to nearer of 0 / min-normal, ties to min-normal
        return sign * (mn if a * 2.0 >= mn else 0.0)
    return sign * y


def fl_encode(x: float, e: int, m: int) -> int:
    """Encode to the (1+e+m)-bit pattern (sign | exponent | mantissa)."""
    q = fl_quantize(x, e, m)
    if q == 0.0:
        return 0
    sign = 1 if q < 0 else 0
    a = abs(q)
    eu = math.floor(math.log2(a))
    sig = a / (2.0 ** eu)
    if sig >= 2.0:
        eu += 1
        sig /= 2.0
    elif sig < 1.0:
        eu -= 1
        sig *= 2.0
    field = eu + fl_bias(e)
    man = int(round((sig - 1.0) * (2 ** m)))
    assert 1 <= field <= 2 ** e - 1, (x, e, m, field)
    return (sign << (e + m)) | (field << m) | man


def fl_decode(bits: int, e: int, m: int) -> float:
    man = bits & ((1 << m) - 1)
    field = (bits >> m) & ((1 << e) - 1)
    sign = (bits >> (e + m)) & 1
    if field == 0:
        return 0.0
    v = (1.0 + man / float(2 ** m)) * 2.0 ** (field - fl_bias(e))
    return -v if sign else v


# ---------------------------------------------------------------------------
# DRUM(k) — dynamic-range unbiased multiplier (unsigned integer core)
# ---------------------------------------------------------------------------


def drum_approx_operand(a: int, k: int) -> int:
    """DRUM operand conditioning: keep the k bits below/at the leading one,
    force the LSB of the kept window to 1 (unbiasing), zero the rest."""
    if a < (1 << k):
        return a
    t = a.bit_length() - 1        # leading-one position
    sh = t - k + 1                # bits dropped
    return ((a >> sh) | 1) << sh


def drum_mul(a: int, b: int, k: int) -> int:
    """DRUM(k) product of two unsigned integers."""
    return drum_approx_operand(a, k) * drum_approx_operand(b, k)


def h_mul(x: float, y: float, i: int, f: int, t: int) -> float:
    """H(i, f, t): quantize to FI(i,f), multiply magnitudes with DRUM(t),
    saturate the product back into FI(i,f) (the datapath keeps 2f fractional
    bits internally; the result is re-quantized to the representation)."""
    ka = fi_encode(x, i, f)
    kb = fi_encode(y, i, f)
    mask = (1 << (i + f)) - 1
    sa, ma = (ka >> (i + f)) & 1, ka & mask
    sb, mb = (kb >> (i + f)) & 1, kb & mask
    prod = drum_mul(ma, mb, t)           # 2(i+f) bits, 2f fractional
    v = prod / float(2 ** (2 * f))
    v = fi_quantize(v, i, f)
    neg = (sa ^ sb) == 1 and v != 0.0
    return -v if neg else v


# ---------------------------------------------------------------------------
# CFPU — configurable floating-point multiplier (approximate)
# ---------------------------------------------------------------------------


def _fl_parts(x: float, e: int, m: int):
    """Decompose a (quantized) FL(e,m) value into (sign, exp_field, mantissa).
    Returns None for zero."""
    bits = fl_encode(x, e, m)
    man = bits & ((1 << m) - 1)
    field = (bits >> m) & ((1 << e) - 1)
    sign = (bits >> (e + m)) & 1
    if field == 0:
        return None
    return sign, field, man


def cfpu_mul(x: float, y: float, e: int, m: int, w: int) -> float:
    """CFPU(w): approximate FL(e,m) multiply.

    The mantissa multiplier is skipped when one operand's mantissa is close
    to a power of two: if the top ``w`` mantissa bits of an operand are all
    zero the product is approximated by the *other* operand with exponents
    added; if they are all one, the same with an exponent increment
    (operand ~ next power of two).  Otherwise falls back to the exact
    multiply (rounded to FL(e,m)).  This is the "configurable" tuning knob
    of Imani et al. (DAC'17) generalized to arbitrary e/m.
    """
    px = _fl_parts(x, e, m)
    py = _fl_parts(y, e, m)
    if px is None or py is None:
        return 0.0
    sx, fx, mx = px
    sy, fy, my = py
    sign = -1.0 if (sx ^ sy) else 1.0
    top = (1 << w) - 1
    bias = fl_bias(e)

    def approx(keep_field: int, keep_man: int, drop_field: int,
               round_up: bool) -> float:
        eu = (keep_field - bias) + (drop_field - bias) + (1 if round_up else 0)
        y_ = (1.0 + keep_man / float(2 ** m)) * 2.0 ** eu
        y_ = min(y_, fl_max(e, m))
        mn = fl_min_normal(e)
        if y_ < mn:
            y_ = mn if y_ * 2.0 >= mn else 0.0
        return sign * y_

    if w <= m:
        ytop = (my >> (m - w)) & top
        if ytop == 0:
            return approx(fx, mx, fy, False)
        if ytop == top:
            return approx(fx, mx, fy, True)
        xtop = (mx >> (m - w)) & top
        if xtop == 0:
            return approx(fy, my, fx, False)
        if xtop == top:
            return approx(fy, my, fx, True)
    # exact fallback
    xv = fl_decode(fl_encode(x, e, m), e, m)
    yv = fl_decode(fl_encode(y, e, m), e, m)
    return fl_quantize(xv * yv, e, m)


# ---------------------------------------------------------------------------
# Mitchell logarithmic multiplier (unsigned integer core)
# ---------------------------------------------------------------------------


def mitchell_mul(a: int, b: int, nfrac: int = 16) -> int:
    """Mitchell's log-multiply on unsigned ints with nfrac-bit log fraction.

    log2(v) ~ t + (v - 2^t)/2^t for v = 2^t + r.  The antilog uses the same
    linear approximation.  Returns an integer approximation of a*b.
    """
    if a == 0 or b == 0:
        return 0

    def log2_fix(v: int) -> int:
        t = v.bit_length() - 1
        frac = ((v - (1 << t)) << nfrac) >> t
        return (t << nfrac) | frac

    s = log2_fix(a) + log2_fix(b)
    t = s >> nfrac
    frac = s & ((1 << nfrac) - 1)
    # antilog: 2^(t+frac) ~ 2^t * (1 + frac)
    if t >= nfrac:
        return ((1 << nfrac) + frac) << (t - nfrac)
    return ((1 << nfrac) + frac) >> (nfrac - t)


# ---------------------------------------------------------------------------
# Truncated multiplier (Chang & Satzoda style, generalized width)
# ---------------------------------------------------------------------------


def truncated_mul(a: int, b: int, n: int, keep: int) -> int:
    """n x n unsigned multiply that discards partial-product columns below
    column ``n - keep`` and adds a constant compensation term of half the
    expected dropped weight."""
    if keep >= n:
        return a * b
    cut = n - keep            # lowest `cut` columns dropped
    acc = 0
    for j in range(n):
        if not ((b >> j) & 1):
            continue
        pp = a << j
        acc += (pp >> cut) << cut
    comp = 1 << (cut - 1) if cut >= 1 else 0
    return acc + comp


# ---------------------------------------------------------------------------
# Lower-part-OR adder (LOA)
# ---------------------------------------------------------------------------


def loa_add(a: int, b: int, l: int) -> int:
    """Approximate adder: exact add on the high part, bitwise OR on the low
    ``l`` bits, carry-in generated by AND of the MSBs of the low parts."""
    if l == 0:
        return a + b
    mask = (1 << l) - 1
    lo = (a & mask) | (b & mask)
    cin = ((a >> (l - 1)) & 1) & ((b >> (l - 1)) & 1)
    hi = (a >> l) + (b >> l) + cin
    return (hi << l) | lo


# ---------------------------------------------------------------------------
# SSM — static segment multiplier (Narayanamoorthy et al., TVLSI'15)
# ---------------------------------------------------------------------------


def ssm_segment(a: int, w: int, n: int) -> tuple[int, int]:
    """Pick the n-bit segment of a w-bit operand: the high segment
    [w-1 .. w-n] when any of its bits is set, else the low segment
    [n-1 .. 0].  Returns (segment_value, shift).

    Requires 2n >= w so the two static positions cover every operand
    (the TVLSI'15 design point, e.g. 8-bit segments of 16-bit operands);
    narrower segments need the multi-position variant."""
    assert 0 < n <= w and 2 * n >= w, (w, n)
    hi = a >> (w - n)
    if hi != 0:
        return hi, w - n
    return a & ((1 << n) - 1), 0


def ssm_mul(a: int, b: int, w: int, n: int) -> int:
    """SSM product: multiply the two n-bit segments exactly, shift back.
    Unlike DRUM the segment positions are static (two choices), which
    simplifies the mux network at a higher worst-case error."""
    sa, sha = ssm_segment(a, w, n)
    sb, shb = ssm_segment(b, w, n)
    return (sa * sb) << (sha + shb)
