"""Build-time trainer: float32 DCNN on the synthetic digit set.

Runs once inside ``make artifacts`` (invoked from aot.py) and produces the
trained parameter set every downstream experiment uses.  Hand-rolled Adam —
no optax in this environment; this is build-path-only Python anyway.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as dataset
from .model import forward_train, init_params, param_names


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logz, labels[:, None], axis=1))


def adam_init(params: dict) -> dict:
    return {
        "m": {k: jnp.zeros_like(v) for k, v in params.items()},
        "v": {k: jnp.zeros_like(v) for k, v in params.items()},
        "t": jnp.zeros((), jnp.int32),
    }


def adam_update(params: dict, grads: dict, state: dict, lr: float,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    sc = lr * jnp.sqrt(1 - b2 ** tf) / (1 - b1 ** tf)
    new = {k: params[k] - sc * m[k] / (jnp.sqrt(v[k]) + eps) for k in params}
    return new, {"m": m, "v": v, "t": t}


@jax.jit
def _train_step(params, state, xb, yb, lr):
    def loss_fn(p):
        return cross_entropy(forward_train(p, xb), yb)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, state = adam_update(params, grads, state, lr)
    return params, state, loss


@jax.jit
def _predict(params, xb):
    return jnp.argmax(forward_train(params, xb), axis=1)


def evaluate(params: dict, x: np.ndarray, y: np.ndarray,
             batch: int = 250) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i:i + batch])[..., None]
        pred = np.asarray(_predict(params, xb))
        correct += int((pred == y[i:i + batch]).sum())
    return correct / len(x)


def train(steps: int = 300, batch: int = 64, lr: float = 2e-3,
          n_train: int = 8000, n_test: int = 2000, seed: int = 7,
          verbose: bool = True):
    """Train and return (params, train_set, test_set, test_accuracy)."""
    tr_u8, tr_y = dataset.generate(n_train, seed=seed)
    te_u8, te_y = dataset.generate(n_test, seed=seed + 1)
    tr_x = dataset.to_float(tr_u8)
    te_x = dataset.to_float(te_u8)

    params = init_params(seed=0)
    state = adam_init(params)
    rng = np.random.default_rng(123)
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, n_train, size=batch)
        xb = jnp.asarray(tr_x[idx])[..., None]
        yb = jnp.asarray(tr_y[idx].astype(np.int32))
        # cosine decay keeps late steps stable at these few-hundred budgets
        cur_lr = lr * 0.5 * (1.0 + np.cos(np.pi * step / steps))
        params, state, loss = _train_step(params, state, xb, yb,
                                          jnp.float32(cur_lr))
        if verbose and (step % 25 == 0 or step == steps - 1):
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)

    acc = evaluate(params, te_x, te_y)
    if verbose:
        print(f"test accuracy (float32 baseline): {acc:.4f}")
    return params, (tr_u8, tr_y), (te_u8, te_y), acc


def save_weights_npz(path: str, params: dict) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_weights_npz(path: str) -> dict:
    z = np.load(path)
    return {k: jnp.asarray(z[k]) for k in z.files}


def save_weights_bin(path: str, params: dict) -> None:
    """LOPW binary format read by rust/src/nn/loader.rs."""
    import struct

    names = param_names()
    with open(path, "wb") as fh:
        fh.write(b"LOPW")
        fh.write(struct.pack("<II", 1, len(names)))
        for name in names:
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            fh.write(struct.pack("<I", len(nb)))
            fh.write(nb)
            fh.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                fh.write(struct.pack("<I", d))
            fh.write(arr.tobytes())
