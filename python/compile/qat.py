"""Quantization-aware retraining (paper §1, question 4):

    "How would converting some pre-trained floating-point weights to
     fixed-point numbers with a predefined bit-width affect prediction
     accuracy ...?  Would retraining using the new representation improve
     the accuracy loss due to conversion?"

Retraining runs the fake-quantized forward (the same `quant.py` primitives
the AOT artifacts use) with a straight-through estimator: gradients flow
through the quantizer as identity, weights update in float32, and the
loss is always computed through the quantized datapath.  Build-path-only
Python, like the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import data as dataset
from .model import forward_train
from .quant import fi_params
from .train import adam_init, adam_update, cross_entropy, evaluate


def ste_quant_params(params: dict, qscalars) -> dict:
    """Fake-quantize weights with a straight-through estimator: the
    quantization error is treated as a constant offset, so d(quant(w))/dw
    = 1 while the forward sees the quantized values."""
    out = {}
    for k, v in params.items():
        layer_idx = {"conv1": 0, "conv2": 1, "fc1": 2, "fc2": 3}[
            k.split("_")[0]]
        scale = qscalars[2 * layer_idx]
        maxk = qscalars[2 * layer_idx + 1]
        mag = jnp.abs(v) * scale
        q = jnp.sign(v) * jnp.minimum(jnp.floor(mag + 0.5), maxk) / scale
        out[k] = v + jax.lax.stop_gradient(q - v)
    return out


def qat_loss(params, xb, yb, qscalars):
    """Cross-entropy through the fully fake-quantized forward: quantized
    weights (STE) and quantized activations (the `fi` fake-quant mode)."""
    qp = ste_quant_params(params, qscalars)
    logits = forward_train(qp, xb, "fi", qscalars)
    return cross_entropy(logits, yb)


def quantized_accuracy(params, x, y, qscalars, batch: int = 250) -> float:
    """Accuracy of the quantized datapath (weights + activations)."""
    correct = 0
    qp = {k: np.asarray(v) for k, v in
          ste_quant_params(params, qscalars).items()}
    qp = {k: jnp.asarray(v) for k, v in qp.items()}
    for i in range(0, len(x), batch):
        xb = jnp.asarray(x[i:i + batch])[..., None]
        logits = forward_train(qp, xb, "fi", qscalars)
        pred = np.asarray(jnp.argmax(logits, axis=1))
        correct += int((pred == y[i:i + batch]).sum())
    return correct / len(x)


def retrain(params: dict, fi_cfg: list[tuple[int, int]], steps: int = 150,
            batch: int = 64, lr: float = 5e-4, n_train: int = 4000,
            seed: int = 7, verbose: bool = True):
    """Retrain `params` under per-layer FI(i, f) quantization.

    Returns (new_params, history) where history records the quantized
    accuracy before and after.
    """
    qscalars = []
    for i, f in fi_cfg:
        qscalars.extend(fi_params(i, f))
    qscalars = [jnp.float32(v) for v in qscalars]

    tr_u8, tr_y = dataset.generate(n_train, seed=seed)
    te_u8, te_y = dataset.generate(1000, seed=seed + 1)
    tr_x = dataset.to_float(tr_u8)
    te_x = dataset.to_float(te_u8)

    before_float = evaluate(params, te_x, te_y)
    before_quant = quantized_accuracy(params, te_x, te_y, qscalars)

    state = adam_init(params)
    step_fn = jax.jit(
        lambda p, s, xb, yb, lr_: _qat_step(p, s, xb, yb, lr_, qscalars))
    rng = np.random.default_rng(11)
    for step in range(steps):
        idx = rng.integers(0, n_train, size=batch)
        xb = jnp.asarray(tr_x[idx])[..., None]
        yb = jnp.asarray(tr_y[idx].astype(np.int32))
        params, state, loss = step_fn(params, state, xb, yb,
                                      jnp.float32(lr))
        if verbose and step % 25 == 0:
            print(f"qat step {step:4d} loss {float(loss):.4f}",
                  flush=True)

    after_quant = quantized_accuracy(params, te_x, te_y, qscalars)
    history = {
        "float_accuracy_before": before_float,
        "quantized_accuracy_before": before_quant,
        "quantized_accuracy_after": after_quant,
    }
    if verbose:
        print(f"quantized accuracy: {before_quant:.4f} -> "
              f"{after_quant:.4f} (float baseline {before_float:.4f})")
    return params, history


def _qat_step(params, state, xb, yb, lr, qscalars):
    loss, grads = jax.value_and_grad(
        lambda p: qat_loss(p, xb, yb, qscalars))(params)
    params, state = adam_update(params, grads, state, lr)
    return params, state, loss
