"""AOT exporter: the single build-time entry point (``make artifacts``).

Produces everything the Rust side consumes:

  artifacts/
    weights.npz           trained float32 parameters (python-side cache)
    weights.bin           LOPW format for rust/src/nn/loader.rs
    dataset.bin           LOPD format for rust/src/data/loader.rs
    ranges.json           per-layer WBA value ranges (paper Table 1)
    meta.json             baseline accuracy + artifact inventory
    fwd_f32_b{B}.hlo.txt  baseline forward, batch B
    fwd_fi_b{B}.hlo.txt   fixed-point fake-quant forward (runtime widths)
    fwd_fl_b{B}.hlo.txt   float(e,m) fake-quant forward (runtime widths)
    golden/*.bin          golden vectors from bitref.py for cargo test

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Parameter order of every fwd artifact (the Rust runtime mirrors this):
    x, conv1_w, conv1_b, conv2_w, conv2_b, fc1_w, fc1_b, fc2_w, fc2_b
    [, q0..q7]   (fi/fl variants: two quant scalars per layer, f32)
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import bitref
from . import data as dataset
from . import train as trainer
from .model import activation_ranges, forward, param_names

BATCH_SIZES = (1, 16, 64)


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_forward(params: dict, batch: int, mode: str) -> str:
    """Lower one forward variant to HLO text with weights as parameters."""
    names = param_names()

    if mode == "none":
        def fn(x, *weights):
            p = dict(zip(names, weights))
            return (forward(p, x, "none"),)
        args = [jax.ShapeDtypeStruct((batch, 28, 28, 1), jnp.float32)]
        args += [jax.ShapeDtypeStruct(np.asarray(params[n]).shape,
                                      jnp.float32) for n in names]
    else:
        def fn(x, *rest):
            weights, qs = rest[:8], rest[8:]
            p = dict(zip(names, weights))
            return (forward(p, x, mode, qs),)
        args = [jax.ShapeDtypeStruct((batch, 28, 28, 1), jnp.float32)]
        args += [jax.ShapeDtypeStruct(np.asarray(params[n]).shape,
                                      jnp.float32) for n in names]
        args += [jax.ShapeDtypeStruct((), jnp.float32)] * 8

    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# golden vectors (bitref -> rust cross-validation)
# ---------------------------------------------------------------------------


def _write_golden(path: str, fmt: str, records: list[tuple]) -> None:
    rec = struct.Struct("<" + fmt)
    with open(path, "wb") as fh:
        fh.write(b"LOPG")
        fh.write(struct.pack("<III", 1, len(records), rec.size))
        for r in records:
            fh.write(rec.pack(*r))


def write_golden_vectors(outdir: str, seed: int = 42) -> dict:
    os.makedirs(outdir, exist_ok=True)
    rng = np.random.default_rng(seed)
    inventory = {}

    # ---- FI quantization: (x f32, i u32, f u32, y f32)
    recs = []
    cfgs = [(4, 8), (6, 8), (5, 10), (2, 3), (0, 7), (8, 0), (1, 1)]
    xs = np.concatenate([
        rng.normal(0, 10, 400), rng.normal(0, 0.05, 200),
        np.array([0.0, -0.0, 1e-9, -1e-9, 1e6, -1e6, 0.5, -0.5]),
    ]).astype(np.float32)
    for i, f in cfgs:
        # exact-tie inputs for the rounding path
        ties = (np.arange(-8, 8) + 0.5) / float(2 ** f)
        for x in np.concatenate([xs, ties.astype(np.float32)]):
            recs.append((float(x), i, f, bitref.fi_quantize(float(x), i, f)))
    _write_golden(os.path.join(outdir, "fi_quant.bin"), "fIIf", recs)
    inventory["fi_quant"] = len(recs)

    # ---- FL quantization: (x f32, e u32, m u32, y f32)
    recs = []
    cfgs = [(4, 8), (4, 9), (5, 10), (3, 4), (2, 2), (7, 15), (4, 1)]
    mags = np.exp(rng.uniform(np.log(1e-6), np.log(1e6), 500))
    signs = rng.choice([-1.0, 1.0], 500)
    xs = np.concatenate([
        (mags * signs), np.array([0.0, -0.0, 1.0, -1.0, 1.5, 2.0 ** 20,
                                  -2.0 ** 20, 3e-5, 2.0 ** -40]),
    ]).astype(np.float32)
    for e, m in cfgs:
        for x in xs:
            recs.append((float(x), e, m, bitref.fl_quantize(float(x), e, m)))
    _write_golden(os.path.join(outdir, "fl_quant.bin"), "fIIf", recs)
    inventory["fl_quant"] = len(recs)

    # ---- DRUM: (a u64, b u64, k u32, pad u32, prod u64)
    recs = []
    for nbits, k in [(8, 4), (14, 6), (16, 12), (16, 14), (22, 8)]:
        a = rng.integers(0, 1 << nbits, 300)
        b = rng.integers(0, 1 << nbits, 300)
        for aa, bb in zip(a, b):
            recs.append((int(aa), int(bb), k, 0,
                         bitref.drum_mul(int(aa), int(bb), k)))
    _write_golden(os.path.join(outdir, "drum.bin"), "QQIIQ", recs)
    inventory["drum"] = len(recs)

    # ---- CFPU: (x f32, y f32, e u32, m u32, w u32, pad u32, res f32, pad f32)
    recs = []
    for e, m, w in [(4, 9, 2), (5, 10, 3), (4, 8, 4), (4, 9, 9)]:
        mags = np.exp(rng.uniform(np.log(1e-3), np.log(1e3), 400))
        xs_ = (mags * rng.choice([-1.0, 1.0], 400)).astype(np.float32)
        ys_ = np.roll(xs_, 1) * 0.7
        special = np.array([1.0, 2.0, 0.5, 1.999, 1.0 + 2 ** -9, 0.0],
                           np.float32)
        xs2 = np.concatenate([xs_, special])
        ys2 = np.concatenate([ys_, np.full(len(special), 3.3, np.float32)])
        for x, y in zip(xs2, ys2):
            recs.append((float(x), float(y), e, m, w, 0,
                         bitref.cfpu_mul(float(x), float(y), e, m, w), 0.0))
    _write_golden(os.path.join(outdir, "cfpu.bin"), "ffIIIIff", recs)
    inventory["cfpu"] = len(recs)

    # ---- H multiplier: (x f32, y f32, i u32, f u32, t u32, pad u32, res f32,
    #                     pad f32)
    recs = []
    for i, f, t in [(6, 8, 12), (8, 8, 14), (6, 8, 6), (4, 4, 3)]:
        xs_ = rng.normal(0, 3, 400).astype(np.float32)
        ys_ = rng.normal(0, 3, 400).astype(np.float32)
        for x, y in zip(xs_, ys_):
            recs.append((float(x), float(y), i, f, t, 0,
                         bitref.h_mul(float(x), float(y), i, f, t), 0.0))
    _write_golden(os.path.join(outdir, "h_mul.bin"), "ffIIIIff", recs)
    inventory["h_mul"] = len(recs)

    # ---- Mitchell: (a u64, b u64, nfrac u32, pad u32, prod u64)
    recs = []
    for nbits, nf in [(8, 16), (16, 16), (16, 8)]:
        a = rng.integers(0, 1 << nbits, 300)
        b = rng.integers(0, 1 << nbits, 300)
        for aa, bb in zip(a, b):
            recs.append((int(aa), int(bb), nf, 0,
                         bitref.mitchell_mul(int(aa), int(bb), nf)))
    _write_golden(os.path.join(outdir, "mitchell.bin"), "QQIIQ", recs)
    inventory["mitchell"] = len(recs)

    # ---- Truncated mul: (a u64, b u64, n u32, keep u32, prod u64)
    recs = []
    for n, keep in [(8, 6), (16, 12), (16, 16), (14, 8)]:
        a = rng.integers(0, 1 << n, 300)
        b = rng.integers(0, 1 << n, 300)
        for aa, bb in zip(a, b):
            recs.append((int(aa), int(bb), n, keep,
                         bitref.truncated_mul(int(aa), int(bb), n, keep)))
    _write_golden(os.path.join(outdir, "truncated.bin"), "QQIIQ", recs)
    inventory["truncated"] = len(recs)

    # ---- SSM: (a u64, b u64, w u32, n u32, prod u64)
    recs = []
    for w, n in [(16, 8), (16, 10), (8, 4), (24, 12)]:
        a = rng.integers(0, 1 << w, 300)
        b = rng.integers(0, 1 << w, 300)
        for aa, bb in zip(a, b):
            recs.append((int(aa), int(bb), w, n,
                         bitref.ssm_mul(int(aa), int(bb), w, n)))
    _write_golden(os.path.join(outdir, "ssm.bin"), "QQIIQ", recs)
    inventory["ssm"] = len(recs)

    # ---- LOA adder: (a u64, b u64, l u32, pad u32, sum u64)
    recs = []
    for nbits, l in [(8, 3), (16, 6), (16, 0), (24, 10)]:
        a = rng.integers(0, 1 << nbits, 300)
        b = rng.integers(0, 1 << nbits, 300)
        for aa, bb in zip(a, b):
            recs.append((int(aa), int(bb), l, 0,
                         bitref.loa_add(int(aa), int(bb), l)))
    _write_golden(os.path.join(outdir, "loa.bin"), "QQIIQ", recs)
    inventory["loa"] = len(recs)

    return inventory


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--retrain", action="store_true",
                    help="retrain even if weights.npz exists")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="skip HLO lowering (tests that only need data)")
    args = ap.parse_args()

    out = args.out
    os.makedirs(out, exist_ok=True)
    meta = {"paper": "Nazemi & Pedram, Lop (2018)", "batch_sizes":
            list(BATCH_SIZES)}

    # ---- train or reload --------------------------------------------------
    wpath = os.path.join(out, "weights.npz")
    if os.path.exists(wpath) and not args.retrain:
        print(f"reusing trained weights: {wpath}", flush=True)
        params = trainer.load_weights_npz(wpath)
        tr_u8, tr_y = dataset.generate(2000, seed=7)
        te_u8, te_y = dataset.generate(2000, seed=8)
        acc = trainer.evaluate(params, dataset.to_float(te_u8), te_y)
    else:
        params, (tr_u8full, tr_y_full), (te_u8, te_y), acc = trainer.train(
            steps=args.steps, n_train=8000, n_test=2000, seed=7)
        trainer.save_weights_npz(wpath, params)
        # keep a 2000-image slice of the training set for range profiling
        tr_u8, tr_y = tr_u8full[:2000], tr_y_full[:2000]
    print(f"baseline float32 test accuracy: {acc:.4f}", flush=True)
    meta["baseline_accuracy"] = acc

    trainer.save_weights_bin(os.path.join(out, "weights.bin"), params)
    dataset.write_dataset_bin(os.path.join(out, "dataset.bin"),
                              tr_u8, tr_y, te_u8, te_y)

    # ---- Table 1: value ranges --------------------------------------------
    ranges = activation_ranges(params,
                               jnp.asarray(dataset.to_float(tr_u8))[..., None])
    with open(os.path.join(out, "ranges.json"), "w") as fh:
        json.dump(ranges, fh, indent=1)
    print("ranges.json written (Table 1):", flush=True)
    for layer, r in ranges.items():
        print(f"  {layer:6s} range [{r['range'][0]:.2f}, "
              f"{r['range'][1]:.2f}]", flush=True)

    # ---- golden vectors ----------------------------------------------------
    inv = write_golden_vectors(os.path.join(out, "golden"))
    meta["golden"] = inv
    print(f"golden vectors: {sum(inv.values())} records", flush=True)

    # ---- HLO artifacts ------------------------------------------------------
    hashes = {}
    if not args.skip_hlo:
        for mode, tag in (("none", "f32"), ("fi", "fi"), ("fl", "fl")):
            for b in BATCH_SIZES:
                name = f"fwd_{tag}_b{b}.hlo.txt"
                print(f"lowering {name} ...", flush=True)
                text = lower_forward(params, b, mode)
                p = os.path.join(out, name)
                with open(p, "w") as fh:
                    fh.write(text)
                hashes[name] = hashlib.sha256(text.encode()).hexdigest()[:16]
                print(f"  {len(text)} chars", flush=True)
    meta["hlo"] = hashes

    with open(os.path.join(out, "meta.json"), "w") as fh:
        json.dump(meta, fh, indent=1)
    print("artifacts complete.", flush=True)


if __name__ == "__main__":
    sys.exit(main())
