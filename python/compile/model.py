"""L2: the paper's DCNN (Fig. 2) in JAX, calling the L1 Pallas kernel.

Architecture (paper Fig. 2, MNIST-shaped):

    input  [B, 28, 28, 1]
    CONV1  5x5x1x32, pad 2, ReLU, 2x2 maxpool   -> [B, 14, 14, 32]
    CONV2  5x5x32x64, pad 2, ReLU, 2x2 maxpool  -> [B, 7, 7, 64]
    FC1    3136x1024, ReLU                      -> [B, 1024]
    FC2    1024x10                              -> [B, 10]  (logits)

Two forward implementations share the same parameter pytree:

  * ``forward``       — im2col + the Pallas ``qmatmul`` kernel; this is what
    gets AOT-lowered to HLO for the Rust runtime (variants f32 / fi / fl,
    with per-layer quantization scalars as runtime parameters).
  * ``forward_train`` — ``lax.conv_general_dilated``-based, used by the
    build-time trainer (fast under jit on CPU) and as a cross-check oracle.

Quantization semantics (must mirror rust/src/nn): values are snapped onto
the representation lattice as they enter each layer's MAC array (weights and
biases are pre-quantized by the caller); partial sums accumulate wide — the
paper widens the integral-bit BCI to cover partial-sum range (§4.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels.qmatmul import qmatmul
from .quant import fake_quant_fi, fake_quant_fl

LAYERS = ("conv1", "conv2", "fc1", "fc2")
CONV_SHAPES = {"conv1": (5, 5, 1, 32), "conv2": (5, 5, 32, 64)}
FC_SHAPES = {"fc1": (3136, 1024), "fc2": (1024, 10)}


def init_params(seed: int = 0) -> dict:
    """Glorot-uniform initialization for all four layers."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shp in CONV_SHAPES.items():
        fan_in = shp[0] * shp[1] * shp[2]
        fan_out = shp[0] * shp[1] * shp[3]
        lim = np.sqrt(6.0 / (fan_in + fan_out))
        params[f"{name}_w"] = rng.uniform(-lim, lim, shp).astype(np.float32)
        params[f"{name}_b"] = np.zeros(shp[3], np.float32)
    for name, shp in FC_SHAPES.items():
        lim = np.sqrt(6.0 / (shp[0] + shp[1]))
        params[f"{name}_w"] = rng.uniform(-lim, lim, shp).astype(np.float32)
        params[f"{name}_b"] = np.zeros(shp[1], np.float32)
    return {k: jnp.asarray(v) for k, v in params.items()}


def param_names() -> list[str]:
    """Canonical parameter order used by every artifact and weights.bin."""
    return [f"{l}_{s}" for l in LAYERS for s in ("w", "b")]


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def im2col(x: jnp.ndarray, kh: int, kw: int, pad: int) -> jnp.ndarray:
    """[B,H,W,C] -> [B*H*W, kh*kw*C] patches (stride 1, zero padding).

    Patch layout is (ky, kx, c) fastest-last — the Rust engine's im2col in
    rust/src/nn/conv.rs uses the identical layout so weights interchange.
    """
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            cols.append(xp[:, ky:ky + h, kx:kx + w, :])
    patches = jnp.stack(cols, axis=3)          # [B,H,W,kh*kw,C]
    return patches.reshape(b * h * w, kh * kw * c)


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pooling, stride 2, on [B,H,W,C]."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def _quant(x: jnp.ndarray, mode: str, q0, q1) -> jnp.ndarray:
    if mode == "fi":
        return fake_quant_fi(x, q0, q1)
    if mode == "fl":
        return fake_quant_fl(x, jnp.asarray(q0, jnp.int32),
                             jnp.asarray(q1, jnp.int32))
    return x


# ---------------------------------------------------------------------------
# Pallas-backed forward (this is what gets AOT-lowered)
# ---------------------------------------------------------------------------


def forward(params: dict, x: jnp.ndarray, mode: str = "none",
            qscalars=None) -> jnp.ndarray:
    """Forward pass through im2col + the Pallas qmatmul kernel.

    x: [B, 28, 28, 1] f32 in [0, 1].
    mode: 'none' (f32 baseline) | 'fi' | 'fl'.
    qscalars: sequence of 8 scalars (q0, q1 per layer, in LAYERS order);
      runtime parameters of the lowered HLO.
    Returns logits [B, 10].
    """
    if mode == "none":
        q = [(0.0, 0.0)] * 4
    else:
        assert qscalars is not None and len(qscalars) == 8
        q = [(qscalars[2 * i], qscalars[2 * i + 1]) for i in range(4)]

    b = x.shape[0]

    # CONV1
    w, bias = params["conv1_w"], params["conv1_b"]
    cols = im2col(x, 5, 5, 2)
    z = qmatmul(cols, w.reshape(-1, w.shape[-1]), mode, q[0][0], q[0][1])
    z = (z + bias).reshape(b, 28, 28, 32)
    a = maxpool2(jax.nn.relu(z))               # [B,14,14,32]

    # CONV2
    w, bias = params["conv2_w"], params["conv2_b"]
    cols = im2col(a, 5, 5, 2)
    z = qmatmul(cols, w.reshape(-1, w.shape[-1]), mode, q[1][0], q[1][1])
    z = (z + bias).reshape(b, 14, 14, 64)
    a = maxpool2(jax.nn.relu(z))               # [B,7,7,64]

    # FC1  (flatten layout (h, w, c) — Rust engine flattens identically)
    a = a.reshape(b, -1)
    z = qmatmul(a, params["fc1_w"], mode, q[2][0], q[2][1])
    a = jax.nn.relu(z + params["fc1_b"])

    # FC2
    z = qmatmul(a, params["fc2_w"], mode, q[3][0], q[3][1])
    return z + params["fc2_b"]


# ---------------------------------------------------------------------------
# lax.conv-backed forward (trainer + oracle)
# ---------------------------------------------------------------------------


def forward_train(params: dict, x: jnp.ndarray, mode: str = "none",
                  qscalars=None) -> jnp.ndarray:
    """Same math as ``forward`` but with lax.conv — fast under jit."""
    if mode == "none":
        q = [(0.0, 0.0)] * 4
    else:
        q = [(qscalars[2 * i], qscalars[2 * i + 1]) for i in range(4)]
    b = x.shape[0]

    def conv(inp, w, q0, q1):
        inp = _quant(inp, mode, q0, q1)
        return lax.conv_general_dilated(
            inp, w, window_strides=(1, 1), padding=((2, 2), (2, 2)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    z = conv(x, params["conv1_w"], *q[0]) + params["conv1_b"]
    a = maxpool2(jax.nn.relu(z))
    z = conv(a, params["conv2_w"], *q[1]) + params["conv2_b"]
    a = maxpool2(jax.nn.relu(z))
    a = a.reshape(b, -1)
    a = _quant(a, mode, *q[2])
    a = jax.nn.relu(a @ params["fc1_w"] + params["fc1_b"])
    a = _quant(a, mode, *q[3])
    return a @ params["fc2_w"] + params["fc2_b"]


def activation_ranges(params: dict, x: jnp.ndarray) -> dict:
    """Per-layer [min, max] over weights, biases and layer outputs —
    reproduces the paper's Table 1 (value range of the WBA set)."""
    b = x.shape[0]
    outs = {}

    def conv(inp, w):
        return lax.conv_general_dilated(
            inp, w, window_strides=(1, 1), padding=((2, 2), (2, 2)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    z1 = conv(x, params["conv1_w"]) + params["conv1_b"]
    a1 = maxpool2(jax.nn.relu(z1))
    z2 = conv(a1, params["conv2_w"]) + params["conv2_b"]
    a2 = maxpool2(jax.nn.relu(z2))
    f = a2.reshape(b, -1)
    z3 = f @ params["fc1_w"] + params["fc1_b"]
    a3 = jax.nn.relu(z3)
    z4 = a3 @ params["fc2_w"] + params["fc2_b"]
    for name, z in zip(LAYERS, (z1, z2, z3, z4)):
        w, bias = params[f"{name}_w"], params[f"{name}_b"]
        vals = [float(jnp.min(w)), float(jnp.max(w)),
                float(jnp.min(bias)), float(jnp.max(bias)),
                float(jnp.min(z)), float(jnp.max(z))]
        outs[name] = {"w": vals[0:2], "b": vals[2:4], "a": vals[4:6],
                      "range": [min(vals[0], vals[2], vals[4]),
                                max(vals[1], vals[3], vals[5])]}
    return outs
