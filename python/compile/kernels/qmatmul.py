"""L1 Pallas kernel: fake-quantized tiled matmul (the paper's MAC datapath).

The FPGA datapath in the paper is a 500-PE MAC array fed by narrow
fixed/floating-point operands.  The TPU analogue is an MXU-shaped GEMM tile:
operands are snapped onto the FI(i, f) / FL(e, m) lattice as they enter the
tile (the narrow datapath), products accumulate wide (the paper widens the
integral-bit BCI for exactly this reason — §4.2), and tiles are staged
HBM→VMEM via BlockSpec (the block-RAM double-buffering of the FPGA design).

Lowered with ``interpret=True``: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel is structured for TPU (128-aligned tiles sized
for VMEM) but numerically validated through the interpret path.  See
DESIGN.md §8 (Hardware Adaptation) for the VMEM/MXU estimates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import fake_quant_fi, fake_quant_fl

# MXU-aligned tile sizes.  VMEM working set per grid cell:
#   x tile  BM x K   (bounded by X_TILE_BYTES)
#   w tile  K x BN   (K <= 3136 at BN = 128 -> 1.6 MiB)
#   o tile  BM x BN
# BM adapts to K: small-K layers (the convs, K = 25·C) take tall tiles so
# the grid stays coarse — fewer grid cells means less per-cell dispatch
# overhead on every backend, while the x-tile stays inside the VMEM
# budget.  (§Perf iteration 5: the fixed 128x128 grid spent most of the
# batch-64 forward on grid dispatch, 56 -> ~400 img/s on CPU-PJRT.)
BN = 128
X_TILE_BYTES = 2 * 1024 * 1024  # VMEM budget for the x tile


def pick_bm(m: int, k: int) -> int:
    """Largest 128-multiple M-tile that (a) keeps the x tile under the
    VMEM budget and (b) doesn't exceed ~16 grid rows."""
    cap = max(128, min(4096, (X_TILE_BYTES // max(k * 4, 1)) // 128 * 128))
    need_rows = (m + 15) // 16
    bm = ((need_rows + 127) // 128) * 128
    return int(max(128, min(cap, bm)))


def _pad_to(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = a.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


def _kernel(q0_ref, q1_ref, x_ref, w_ref, o_ref, *, mode: str):
    x = x_ref[...]
    if mode == "fi":
        x = fake_quant_fi(x, q0_ref[0], q1_ref[0])
    elif mode == "fl":
        x = fake_quant_fl(x, q0_ref[0].astype(jnp.int32),
                          q1_ref[0].astype(jnp.int32))
    o_ref[...] = jnp.dot(x, w_ref[...],
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("mode",))
def qmatmul(x: jnp.ndarray, w: jnp.ndarray, mode: str = "none",
            q0=0.0, q1=0.0) -> jnp.ndarray:
    """``fake_quant(x) @ w`` with f32 accumulation, as a Pallas kernel.

    x: [M, K] f32;  w: [K, N] f32 (pre-quantized by the caller — weights are
    snapped onto the representation lattice once, on the Rust side).
    mode: 'none' | 'fi' | 'fl';  q0/q1: the two quantization scalars.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)

    bm = pick_bm(m, k)
    xp = _pad_to(x, 0, bm)
    wp = _pad_to(w, 1, BN)
    mp, np_ = xp.shape[0], wp.shape[1]
    grid = (mp // bm, np_ // BN)

    q0a = jnp.asarray(q0, jnp.float32).reshape(1)
    q1a = jnp.asarray(q1, jnp.float32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(q0a, q1a, xp, wp)
    return out[:m, :n]
