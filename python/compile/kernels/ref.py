"""Pure-jnp oracle for the L1 Pallas kernel (``qmatmul.py``).

This is the correctness reference: pytest asserts the Pallas kernel matches
these functions exactly (they share the fake-quant primitives from
``quant.py``, which are themselves bit-checked against ``bitref.py``).
"""

from __future__ import annotations

import jax.numpy as jnp

from ..quant import fake_quant_fi, fake_quant_fl


def qmatmul_ref(x: jnp.ndarray, w: jnp.ndarray, mode: str = "none",
                q0=None, q1=None) -> jnp.ndarray:
    """Reference quantized matmul: fake-quantize ``x`` (mode 'fi'/'fl'),
    then a plain matmul with f32 accumulation.

    ``q0``/``q1`` are the two quantization scalars:
      mode 'fi' -> (scale, maxk)      (f32; see quant.fi_params)
      mode 'fl' -> (e_bits, m_bits)   (i32)
    """
    if mode == "fi":
        x = fake_quant_fi(x, q0, q1)
    elif mode == "fl":
        x = fake_quant_fl(x, jnp.asarray(q0, jnp.int32),
                          jnp.asarray(q1, jnp.int32))
    elif mode != "none":
        raise ValueError(f"unknown mode {mode!r}")
    return jnp.dot(x, w, preferred_element_type=jnp.float32)
