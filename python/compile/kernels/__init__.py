# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .qmatmul import qmatmul  # noqa: F401
from .ref import qmatmul_ref  # noqa: F401
